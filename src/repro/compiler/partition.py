"""Multi-device program partitioning: one network → N coordinated programs.

N3H-Core's unified ISA and Sync-token hand-shake coordinate two
heterogeneous cores on one FPGA; this module scales the same mechanism
*across* devices. A :class:`PartitionPlan` splits a network over
``n_devices`` accelerators in one of two ways:

  * ``"pipeline"`` — pipeline stages: each device owns a contiguous
    slice of layers (balanced on MACs). Device d hands its boundary
    activations to device d+1 over the chip-to-chip link, synchronized
    by a cross-device Sync pair (``*.xdev`` channels): a send at the
    tail of the producing layer's result stream, a wait at the head of
    the consuming layer's fetch stream.
  * ``"filter"`` — filter-parallel (shard-N): every device owns all
    layers but only a contiguous shard of each layer's output filters
    *in split column order* (the Eq.-12 LUT-partition columns first,
    then the DSP columns — so concatenating device shards reproduces
    the single-device output layout exactly). After every layer each
    device gathers the peer shards it is missing: one ``*.xdev`` wait
    plus one gather DMA (``stage_ctrl=3``, a Fetch over the link into
    the layer's ``L{i}.gather`` segment) per peer, paired with one
    ``*.xdev`` send per peer on the producing side.

The plan kind is derived from the ``parallel/`` logical-axis rules when
not forced: rules that shard filter-like axes (``mlp``/``heads``/
``experts``/``vocab``) over a mesh axis map to ``"filter"``; rules that
shard ``layers`` map to ``"pipeline"``.

:func:`lower_partitioned` compiles the per-device :class:`Program`s
(each through the ordinary ``lower_network`` path, so a 1-device plan
is bit-for-bit the legacy single program) and packages them as a
:class:`MultiDeviceProgram` with an explicit cross-device channel edge
table. :func:`validate_bundle` checks that every edge's token pairing
(sends on the source device, waits on the destination) is intact —
:func:`optimize_bundle` runs the ``passes.py`` pipeline per device and
re-validates, so no pass can silently break a device hand-off.

Timing: :func:`simulate_bundle` aggregates per-device event-driven
simulations into a cross-device makespan under a :class:`LinkModel`
(latency + bandwidth of the device-to-device link; calibration
parameters, like the DMA constants of ``FPGADevice``). Pipeline plans
overlap a stream of ``batches`` inputs (makespan = first-traversal
latency + (batches-1) x steady-state interval); filter plans execute
each layer in data-parallel lockstep (per-layer makespan = max over
devices, gather DMAs included in the streams).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import isa
from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    GemmDims,
    LutCoreConfig,
    Op,
)
from repro.compiler.lower import (
    _clamp16,
    _send,
    _wait,
    lower_network,
    solve_split_dims,
)
from repro.compiler.program import (
    CORE_NAMES,
    CROSS_DEVICE_CHANNELS,
    GemmLayer,
    Program,
)
from repro.parallel.sharding import FILTER_PARALLEL_AXES

PLAN_KINDS = ("pipeline", "filter")

#: gather DMA stage: cross-device link-in (stages 0/1 are weight /
#: activation DDR fetches; see runtime/golden.py's contract checks)
GATHER_STAGE = 3


class PartitionError(RuntimeError):
    """A partition plan is infeasible or a bundle violates it."""


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Device-to-device link timing (calibration parameters).

    ``latency_cycles`` is the fixed hand-off cost per transfer (token
    round-trip + DMA setup across the link); ``bytes_per_cycle`` the
    sustained link bandwidth, deliberately below the on-board DDR's
    ``dma_bytes_per_cycle`` — crossing chips is slower than DRAM.
    """
    latency_cycles: int = 300
    bytes_per_cycle: float = 8.0

    def cycles(self, n_bytes: float) -> int:
        return self.latency_cycles + int(math.ceil(
            n_bytes / self.bytes_per_cycle))


@dataclasses.dataclass(frozen=True)
class ChannelEdge:
    """One cross-device token channel: ``src_device``'s local layer
    ``src_layer`` posts a token consumed by ``dst_device``'s local
    layer ``dst_layer``, moving ``nbytes`` of activations."""
    src_device: int
    src_layer: int
    dst_device: int
    dst_layer: int
    src_channel: str
    dst_channel: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """How one network maps onto ``n_devices`` accelerators.

    ``stages`` (pipeline) — per device a half-open [lo, hi) range over
    the global layer list. ``shards`` (filter) — per *layer* the
    ``n_devices + 1`` column boundaries of the split-order output
    shard each device owns.
    """
    kind: str
    n_devices: int
    stages: tuple[tuple[int, int], ...] | None = None
    shards: tuple[tuple[int, ...], ...] | None = None
    link: LinkModel = LinkModel()

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise PartitionError(
                f"plan kind must be one of {PLAN_KINDS}, got {self.kind!r}")
        if self.n_devices < 1:
            raise PartitionError("plan needs at least one device")
        if self.kind == "pipeline" and self.stages is None:
            raise PartitionError("pipeline plan is missing its stages")
        if self.kind == "filter" and self.shards is None:
            raise PartitionError("filter plan is missing its shards")

    def describe(self) -> str:
        if self.kind == "pipeline":
            spans = " ".join(f"[{lo}:{hi})" for lo, hi in self.stages)
            return f"pipeline x{self.n_devices}  stages {spans}"
        return (f"filter x{self.n_devices}  "
                f"{len(self.shards)} layers sharded on output filters")


# ---------------------------------------------------------------------------
# Plan derivation (from the parallel/ logical-axis rules)
# ---------------------------------------------------------------------------

#: logical axes whose sharding means "split output filters" — owned by
#: parallel/sharding.py (the same names DEFAULT_RULES map onto the
#: model axis), aliased here for the plan derivation.
FILTER_AXES = FILTER_PARALLEL_AXES


def kind_from_rules(rules) -> str:
    """Map a ``parallel.sharding.AxisRules`` table to a plan kind.

    Rules that shard the ``layers`` axis ask for pipeline stages; rules
    that shard filter-like axes (``mlp``/``heads``/``experts``/
    ``vocab`` — the model-parallel dims) ask for filter-parallel
    shards. The stock ``DEFAULT_RULES`` shard mlp/heads over "model",
    so they derive ``"filter"``.
    """
    if rules.lookup("layers"):
        return "pipeline"
    if any(rules.lookup(name) for name in FILTER_AXES):
        return "filter"
    return "pipeline"


def _balanced_stages(layers: list[GemmLayer],
                     n_devices: int) -> tuple[tuple[int, int], ...]:
    """Contiguous layer ranges balanced on MACs (prefix-sum targets)."""
    n = len(layers)
    if n_devices > n:
        raise PartitionError(
            f"pipeline plan needs at least one layer per device "
            f"({n} layers < {n_devices} devices)")
    weights = [max(gl.dims.macs(), 1) for gl in layers]
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)
    total = prefix[-1]
    bounds = [0]
    for d in range(1, n_devices):
        target = total * d / n_devices
        # closest prefix to the target, leaving >=1 layer per stage
        lo = bounds[-1] + 1
        hi = n - (n_devices - d)
        best = min(range(lo, hi + 1),
                   key=lambda i: abs(prefix[i] - target))
        bounds.append(best)
    bounds.append(n)
    return tuple((bounds[d], bounds[d + 1]) for d in range(n_devices))


def _filter_shards(layers: list[GemmLayer],
                   n_devices: int) -> tuple[tuple[int, ...], ...]:
    """Per-layer split-order column boundaries, near-equal widths."""
    shards = []
    for gl in layers:
        n = gl.dims.n
        if n < n_devices:
            raise PartitionError(
                f"layer {gl.name!r} has {n} output filters < "
                f"{n_devices} devices; filter plan infeasible")
        shards.append(tuple(round(n * d / n_devices)
                            for d in range(n_devices + 1)))
    return tuple(shards)


def derive_plan(layers: list[GemmLayer], n_devices: int,
                kind: str | None = None, rules=None,
                link: LinkModel = LinkModel()) -> PartitionPlan:
    """Derive a partition plan for ``layers`` over ``n_devices``.

    ``kind`` falls back to :func:`kind_from_rules` over ``rules`` (the
    ``parallel/`` axis-rule table; ``DEFAULT_RULES`` when None).
    """
    if kind is None:
        if rules is None:
            from repro.parallel.sharding import DEFAULT_RULES as rules
        kind = kind_from_rules(rules)
    if kind == "pipeline":
        return PartitionPlan("pipeline", n_devices,
                             stages=_balanced_stages(layers, n_devices),
                             link=link)
    if kind == "filter":
        return PartitionPlan("filter", n_devices,
                             shards=_filter_shards(layers, n_devices),
                             link=link)
    raise PartitionError(f"unknown plan kind {kind!r}")


# ---------------------------------------------------------------------------
# The multi-device container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiDeviceProgram:
    """One network compiled into a coordinated fleet of per-device
    programs plus the cross-device channel wiring between them."""
    name: str
    plan: PartitionPlan
    devices: list[Program]
    edges: list[ChannelEdge]

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def n_layers(self) -> int:
        """Global layer count of the source network."""
        if self.plan.kind == "pipeline":
            return self.plan.stages[-1][1]
        return len(self.devices[0].layers)

    @property
    def n_instructions(self) -> int:
        return sum(p.n_instructions for p in self.devices)

    def placements(self, global_layer: int) -> list[tuple[int, int]]:
        """[(device, local layer index)] owning ``global_layer``."""
        if self.plan.kind == "pipeline":
            for d, (lo, hi) in enumerate(self.plan.stages):
                if lo <= global_layer < hi:
                    return [(d, global_layer - lo)]
            raise IndexError(f"no stage owns layer {global_layer}")
        if not 0 <= global_layer < self.n_layers:
            raise IndexError(f"no layer {global_layer}")
        return [(d, global_layer) for d in range(self.n_devices)]


# ---------------------------------------------------------------------------
# Lowering: network + plan -> MultiDeviceProgram
# ---------------------------------------------------------------------------


def _per_layer(value, n: int, what: str) -> list:
    out = list(value) if isinstance(value, (list, tuple)) else [value] * n
    if len(out) != n:
        raise ValueError(f"per-layer {what} list must match the layer count")
    return out


def _first_core(lp):
    """The layer's canonical sync core (LUT partition first, as in the
    split column order). Layers with n >= 1 always have one."""
    cp = lp.lut if lp.lut is not None else lp.dsp
    if cp is None:
        raise PartitionError(
            f"layer {lp.index} ({lp.name}) has no active core")
    return cp


def _xdev_send(cp) -> Op:
    c = cp.core
    return _send(c, isa.Engine.RESULT, isa.Engine.FETCH,
                 f"{CORE_NAMES[c]}.xdev")


def _xdev_wait(cp) -> Op:
    c = cp.core
    return _wait(c, isa.Engine.RESULT, isa.Engine.FETCH,
                 f"{CORE_NAMES[c]}.xdev")


def _fetch_insert_at(cp) -> int:
    """Insert point in a fetch stream: after the leading inter-layer
    barrier wait (when present), before everything else."""
    stream = cp.streams["fetch"]
    if (stream and isinstance(stream[0].instr, isa.SyncInstr)
            and stream[0].instr.is_wait
            and stream[0].channel == f"{CORE_NAMES[cp.core]}.bar"):
        return 1
    return 0


def _solved_n_luts(layers, lut_cfg, dsp_cfg, dev, bw, ba,
                   n_luts) -> list[int]:
    """Full-network per-layer neuron splits (given or Eq.-12 solved),
    clamped exactly as ``lower_network`` clamps them."""
    out = []
    for i, gl in enumerate(layers):
        if n_luts is not None:
            out.append(int(min(max(n_luts[i], 0), gl.dims.n)))
        else:
            out.append(solve_split_dims(gl.dims, gl.depthwise, lut_cfg,
                                        dsp_cfg, dev, bw[i], ba[i]))
    return out


def lower_partitioned(name: str, layers: list[GemmLayer],
                      plan: PartitionPlan,
                      lut_cfg: LutCoreConfig, dsp_cfg: DspCoreConfig,
                      dev: FPGADevice,
                      bits_w_lut: int | list[int] = 4,
                      bits_a: int | list[int] = 4,
                      n_luts: list[int] | None = None,
                      opt_level: int = 0,
                      gather_overlap: bool = True) -> MultiDeviceProgram:
    """Compile ``layers`` under ``plan`` into a MultiDeviceProgram.

    Every per-device program goes through the ordinary
    :func:`~repro.compiler.lower.lower_network` path (at ``-O0``; the
    optimization pipeline then runs *per device* via
    :func:`optimize_bundle`, which re-validates the cross-device token
    pairing afterwards). A 1-device plan of either kind reproduces the
    legacy single program bit for bit.

    ``gather_overlap`` (filter plans) places each gather [wait + link
    DMA] pair at the tail of the *producing* layer's fetch stream, so
    the link transfer overlaps that layer's execute/result work instead
    of serializing at the consuming layer's head (the pre-overlap
    behavior, kept under ``gather_overlap=False`` for the makespan
    comparison benchmark).
    """
    nl = len(layers)
    bw = _per_layer(bits_w_lut, nl, "bit")
    ba = _per_layer(bits_a, nl, "bit")
    if plan.kind == "pipeline" and plan.stages[-1][1] != nl:
        raise PartitionError(
            f"plan covers {plan.stages[-1][1]} layers, network has {nl}")
    if plan.kind == "filter" and len(plan.shards) != nl:
        raise PartitionError(
            f"plan shards {len(plan.shards)} layers, network has {nl}")
    splits = _solved_n_luts(layers, lut_cfg, dsp_cfg, dev, bw, ba, n_luts)
    D = plan.n_devices

    def dev_name(d: int) -> str:
        return name if D == 1 else f"{name}@dev{d}"

    if plan.kind == "pipeline":
        progs = [lower_network(dev_name(d), layers[lo:hi], lut_cfg, dsp_cfg,
                               dev, bits_w_lut=bw[lo:hi], bits_a=ba[lo:hi],
                               n_luts=splits[lo:hi])
                 for d, (lo, hi) in enumerate(plan.stages)]
        edges: list[ChannelEdge] = []
        for d in range(D - 1):
            lo, hi = plan.stages[d]
            src_lp = progs[d].layers[-1]
            dst_lp = progs[d + 1].layers[0]
            src_cp, dst_cp = _first_core(src_lp), _first_core(dst_lp)
            g = src_lp.dims
            # boundary activations cross the link at the *consuming*
            # layer's bit-width (they are requantized to it, and the
            # consumer's act fetches/act.in segment are sized with it)
            nbytes = math.ceil(g.m * g.n * dst_lp.bits_a / 8)
            src_cp.streams["result"].append(_xdev_send(src_cp))
            dst_cp.streams["fetch"].insert(_fetch_insert_at(dst_cp),
                                           _xdev_wait(dst_cp))
            edges.append(ChannelEdge(
                src_device=d, src_layer=src_lp.index,
                dst_device=d + 1, dst_layer=dst_lp.index,
                src_channel=f"{CORE_NAMES[src_cp.core]}.xdev",
                dst_channel=f"{CORE_NAMES[dst_cp.core]}.xdev",
                nbytes=nbytes))
        mdp = MultiDeviceProgram(name, plan, progs, edges)
        return optimize_bundle(mdp, opt_level) if opt_level else mdp

    # -- filter-parallel (shard-N over split column order) -----------------
    widths = [[plan.shards[i][d + 1] - plan.shards[i][d]
               for i in range(nl)] for d in range(D)]
    progs = []
    for d in range(D):
        shard_layers = []
        shard_n_luts = []
        for i, gl in enumerate(layers):
            lo, hi = plan.shards[i][d], plan.shards[i][d + 1]
            geom = gl.geometry
            if geom is not None:
                # the device's conv geometry covers only its filter
                # shard; depthwise shards also consume only their own
                # channels' input slices (c_in == c_out)
                geom = dataclasses.replace(
                    geom, c_out=hi - lo,
                    c_in=hi - lo if gl.depthwise else geom.c_in)
            shard_layers.append(GemmLayer(
                gl.name, GemmDims(gl.dims.m, gl.dims.k, hi - lo),
                gl.depthwise, geom, elementwise=gl.elementwise))
            # overlap of [lo, hi) with the LUT columns [0, n_lut)
            shard_n_luts.append(max(0, min(hi, splits[i]) - lo))
        progs.append(lower_network(dev_name(d), shard_layers, lut_cfg,
                                   dsp_cfg, dev, bits_w_lut=bw, bits_a=ba,
                                   n_luts=shard_n_luts))

    edges = []
    if D > 1:
        for d in range(D):
            prog = progs[d]
            for i in range(nl - 1):
                g = layers[i].dims
                # gather segment: the peer shards of layer i's output
                # this device is missing, staged for layer i+1's reads
                # (sized at the consuming layer's activation bits, like
                # the act fetches that read them)
                gather = prog.memory.alloc(
                    f"L{i}.gather",
                    math.ceil(g.m * (g.n - widths[d][i]) * ba[i + 1] / 8))
                src_cp = _first_core(prog.layers[i])
                dst_cp = _first_core(prog.layers[i + 1])
                if gather_overlap:
                    # overlap placement: the gather DMAs ride at the
                    # tail of the *producing* layer's fetch stream, so
                    # the link transfer overlaps that layer's
                    # execute/result work (its xdev wait is armed by the
                    # peer's result-tail send within the same lockstep
                    # layer window)
                    gather_cp, gather_layer = src_cp, i
                    at = len(src_cp.streams["fetch"])
                else:
                    gather_cp, gather_layer = dst_cp, i + 1
                    at = _fetch_insert_at(dst_cp)
                # peer shards stage into the gather segment in device
                # order (self excluded); the DMA's ddr_offset is that
                # staging ordinal, per the tile-index-into-segment
                # convention of the single-device lowerer
                for rank, p in enumerate(q for q in range(D) if q != d):
                    nbytes = math.ceil(g.m * widths[p][i] * ba[i + 1] / 8)
                    # outgoing token for peer p's gather of our shard
                    src_cp.streams["result"].append(_xdev_send(src_cp))
                    # incoming: wait for p's shard, then DMA it over
                    # the link into the gather segment
                    gather_cp.streams["fetch"].insert(
                        at, _xdev_wait(gather_cp))
                    gather_cp.streams["fetch"].insert(at + 1, Op(
                        isa.FetchInstr(gather_cp.core, 0, GATHER_STAGE, 0,
                                       gather.base, rank, _clamp16(nbytes)),
                        cycles=plan.link.cycles(nbytes)))
                    gather_cp.bytes_fetched += nbytes
                    at += 2
                    peer_cp = _first_core(progs[p].layers[i])
                    edges.append(ChannelEdge(
                        src_device=p, src_layer=i,
                        dst_device=d, dst_layer=gather_layer,
                        src_channel=f"{CORE_NAMES[peer_cp.core]}.xdev",
                        dst_channel=f"{CORE_NAMES[gather_cp.core]}.xdev",
                        nbytes=nbytes))
    mdp = MultiDeviceProgram(name, plan, progs, edges)
    return optimize_bundle(mdp, opt_level) if opt_level else mdp


# ---------------------------------------------------------------------------
# Cross-device token-pairing validation + per-device optimization
# ---------------------------------------------------------------------------


def _xdev_counts(prog: Program) -> tuple[dict[int, int], dict[int, int]]:
    """Per-layer (send count, wait count) on cross-device channels."""
    sends: dict[int, int] = {}
    waits: dict[int, int] = {}
    for lp in prog.layers:
        for cp in lp.cores():
            for op in cp.ops():
                if op.channel not in CROSS_DEVICE_CHANNELS:
                    continue
                tgt = waits if op.instr.is_wait else sends
                tgt[lp.index] = tgt.get(lp.index, 0) + 1
    return sends, waits


def validate_bundle(mdp: MultiDeviceProgram) -> None:
    """Check the cross-device token pairing against the edge table.

    Every edge must be backed by exactly one ``*.xdev`` send in the
    source device's producing layer and one ``*.xdev`` wait in the
    destination device's consuming layer — and no stray cross-device
    syncs may exist beyond the edges. Raises :class:`PartitionError`.
    """
    want_send: dict[tuple[int, int], int] = {}
    want_wait: dict[tuple[int, int], int] = {}
    for e in mdp.edges:
        k = (e.src_device, e.src_layer)
        want_send[k] = want_send.get(k, 0) + 1
        k = (e.dst_device, e.dst_layer)
        want_wait[k] = want_wait.get(k, 0) + 1
    for d, prog in enumerate(mdp.devices):
        sends, waits = _xdev_counts(prog)
        for what, have, want in (("send", sends, want_send),
                                 ("wait", waits, want_wait)):
            layers = {li for (dd, li) in want if dd == d} | set(have)
            for li in sorted(layers):
                w = want.get((d, li), 0)
                h = have.get(li, 0)
                if w != h:
                    raise PartitionError(
                        f"device {d} layer {li}: {h} cross-device "
                        f"{what}(s) in streams, edge table expects {w} — "
                        f"token pairing broken")


def optimize_bundle(mdp: MultiDeviceProgram, opt_level: int = 1, *,
                    validate: bool = True) -> MultiDeviceProgram:
    """Run the ``passes.py`` pipeline per device, then re-validate the
    cross-device token pairing (a pass that dropped or duplicated an
    ``*.xdev`` sync would corrupt a remote hand-off silently — the
    per-device deadlock check cannot see it)."""
    from repro.compiler.passes import optimize_program
    if opt_level == 0:
        return mdp
    out = MultiDeviceProgram(
        mdp.name, mdp.plan,
        [optimize_program(p, opt_level, validate=validate)
         for p in mdp.devices],
        list(mdp.edges))
    if validate:
        validate_bundle(out)
    return out


# ---------------------------------------------------------------------------
# Cross-device makespan aggregation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BundleSim:
    """Aggregate timing of a multi-device traversal.

    ``device_sims`` are the per-device event-driven ``ProgramSim``s
    (gather DMAs and their link cycles are already in the streams for
    filter plans). Pipeline plans overlap ``batches`` inputs:
    makespan = first-traversal latency + (batches-1) x steady-state
    interval, where the interval is the slowest stage or link edge.
    Filter plans run layers in data-parallel lockstep: per-layer
    makespan is the max over devices, and batches do not overlap.
    """
    kind: str
    batches: int
    device_sims: list            # list[ProgramSim]
    edge_cycles: list[int]       # per ChannelEdge link cost (pipeline)

    @property
    def stage_cycles(self) -> list[int]:
        return [s.total_cycles for s in self.device_sims]

    @property
    def latency_cycles(self) -> int:
        """One traversal: input enters device 0, result leaves the end."""
        if self.kind == "pipeline":
            return sum(self.stage_cycles) + sum(self.edge_cycles)
        n_layers = len(self.device_sims[0].layers)
        return sum(max(s.layers[i].cycles for s in self.device_sims)
                   for i in range(n_layers))

    @property
    def interval_cycles(self) -> int:
        """Steady-state cycles between consecutive results."""
        if self.kind == "pipeline":
            return max(self.stage_cycles + (self.edge_cycles or [0]))
        return self.latency_cycles

    @property
    def total_cycles(self) -> int:
        """Makespan of ``batches`` back-to-back inputs."""
        return (self.latency_cycles
                + (self.batches - 1) * self.interval_cycles)

    @property
    def n_instructions(self) -> int:
        return sum(s.n_instructions for s in self.device_sims)

    def decomposition(self, core: str) -> dict[str, int]:
        agg = {"l_wait": 0, "l_run": 0, "l_sig": 0, "l_rst": 0}
        for s in self.device_sims:
            d = s.decomposition(core)
            for k in agg:
                agg[k] += d[k]
        return agg


def simulate_bundle(mdp: MultiDeviceProgram, batches: int = 1,
                    tracer=None) -> BundleSim:
    """Per-device event-driven simulation + cross-device aggregation.

    ``tracer`` (a ``repro.obs.Tracer``; default off) records every
    device's spans on its own track group, placed on the bundle's
    global timeline: pipeline stages start after the prior stages and
    link edges they wait on (link transfers get their own track), and
    filter plans share the per-layer cross-device-max window so the
    lockstep idle shows up explicitly. The trace decomposes one
    traversal — its makespan is ``latency_cycles`` (== ``total_cycles``
    at ``batches=1``, the configuration the closure tests pin).
    """
    from repro.core.scheduler import (ProgramSim, record_program_trace,
                                      simulate_layers)
    tracing = tracer is not None and getattr(tracer, "enabled", False)
    sims = [ProgramSim(simulate_layers(p, collect_traces=tracing))
            for p in mdp.devices]
    edge_cycles = [mdp.plan.link.cycles(e.nbytes) for e in mdp.edges] \
        if mdp.plan.kind == "pipeline" else []
    bs = BundleSim(kind=mdp.plan.kind, batches=max(1, int(batches)),
                   device_sims=sims, edge_cycles=edge_cycles)
    if not tracing:
        return bs
    latency = bs.latency_cycles
    if mdp.plan.kind == "pipeline":
        offset = 0
        for d, (prog, ps) in enumerate(zip(mdp.devices, sims)):
            record_program_trace(tracer, d, prog.device.name, prog,
                                 ps.layers, offset=offset)
            # everything outside this device's own stage window —
            # upstream/downstream stages and the link edges — is idle
            # for all six of its tracks
            tracer.pad_idle(d, latency - ps.total_cycles)
            offset += ps.total_cycles
            for e, c in zip(mdp.edges, edge_cycles):
                if e.src_device != d:
                    continue
                tracer.record_link(d, e.dst_device, offset, c, e.nbytes,
                                   f"L{e.src_layer}->L{e.dst_layer}")
                offset += c
    else:  # filter: data-parallel lockstep, shared per-layer windows
        n_layers = len(sims[0].layers)
        windows = [max(s.layers[i].cycles for s in sims)
                   for i in range(n_layers)]
        for d, (prog, ps) in enumerate(zip(mdp.devices, sims)):
            record_program_trace(tracer, d, prog.device.name, prog,
                                 ps.layers, windows=windows)
    tracer.set_makespan(latency)
    return bs


# ---------------------------------------------------------------------------
# Decode-resident bundles (multi-device autoregressive serving)
# ---------------------------------------------------------------------------


def decorate_decode_bundle(mdp: MultiDeviceProgram, step) -> MultiDeviceProgram:
    """Apply :func:`~repro.compiler.lower.decorate_decode` to every
    per-device program in place: weight segments become resident, and
    each device's attention/SSM shard gains its own (shard-sized)
    KV-cache/state segment plus the persistent read/append DMAs. The
    decoration adds no cross-device syncs, so the edge table is
    untouched (re-validated to be sure)."""
    from repro.compiler.lower import decorate_decode
    for p in mdp.devices:
        decorate_decode(p, step)
    validate_bundle(mdp)
    return mdp


def steady_bundle(mdp: MultiDeviceProgram) -> MultiDeviceProgram:
    """The steady-state decode variant of a decorated bundle: each
    device program through :func:`~repro.compiler.lower.steady_program`
    (weight fetches elided, their tokens pre-armed); the cross-device
    hand-offs are untouched, so the edge table carries over verbatim."""
    from repro.compiler.lower import steady_program
    out = MultiDeviceProgram(f"{mdp.name}.steady", mdp.plan,
                             [steady_program(p) for p in mdp.devices],
                             list(mdp.edges))
    validate_bundle(out)
    return out
