"""``python -m repro.compiler`` — compile networks to ISA programs.

Examples::

    python -m repro.compiler resnet18                   # summary
    python -m repro.compiler llama3.2-1b --format asm   # text assembly
    python -m repro.compiler mobilenet_v2 --format bin -o mb2.n3h
    python -m repro.compiler resnet18 --simulate        # + Fig.5 decomposition
    python -m repro.compiler resnet18 -O 1 --simulate   # optimized streams
    python -m repro.compiler llama3.2-1b -O 1 --execute --backend pallas
    python -m repro.compiler llama3.2-1b --devices 2 --partition pipeline \
        --simulate                                      # multi-device bundle
    python -m repro.compiler --list
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.scheduler import (
    DEVICES,
    DspCoreConfig,
    LutCoreConfig,
    simulate_program,
)
from repro.quant.uniform import qrange
from repro.compiler import asm
from repro.compiler.lower import lower_network
from repro.compiler.networks import list_networks, network_layers
from repro.compiler.partition import (
    PLAN_KINDS,
    LinkModel,
    PartitionError,
    derive_plan,
    lower_partitioned,
)
from repro.compiler.passes import OPT_LEVELS
from repro.compiler.runtime import (
    BACKENDS,
    MultiDeviceExecutor,
    bind_synthetic,
    get_backend,
)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.compiler",
        description="Compile a network to unified-ISA instruction streams.")
    p.add_argument("network", nargs="?",
                   help="resnet18 | mobilenet_v2 | any registered arch id")
    p.add_argument("--list", action="store_true",
                   help="list compilable networks and exit")
    p.add_argument("--device", default="XC7Z020", choices=sorted(DEVICES))
    p.add_argument("--bits-w", type=int, default=4,
                   help="LUT-core weight bit-width (2-8)")
    p.add_argument("--bits-a", type=int, default=4,
                   help="activation bit-width (2-8)")
    p.add_argument("--ratio", type=float, default=None,
                   help="fixed LUT filter ratio; default solves Eq. 12")
    p.add_argument("--seq-len", type=int, default=64,
                   help="token count for LM archs")
    p.add_argument("--decode", action="store_true",
                   help="compile an autoregressive decode step program "
                        "(m = --batch) with resident weights and "
                        "KV-cache/state segments instead of the "
                        "fixed-sequence program")
    p.add_argument("--batch", type=int, default=1,
                   help="sequences per decode step (--decode)")
    p.add_argument("--max-seq", type=int, default=64,
                   help="KV-cache/state depth of a decode session "
                        "(--decode)")
    p.add_argument("--in-hw", type=int, default=None,
                   help="CNN input size (default 224); reduced variants "
                        "stay geometry-consistent end to end")
    p.add_argument("--width", type=float, default=None,
                   help="CNN channel-width multiplier (default 1.0)")
    p.add_argument("--lut-m", type=int, default=8)
    p.add_argument("--lut-n", type=int, default=16)
    p.add_argument("--lut-k", type=int, default=128)
    p.add_argument("--devices", type=int, default=1,
                   help="compile for N coordinated devices (a "
                        "multi-device bundle when N > 1 or --partition "
                        "is given)")
    p.add_argument("--partition", choices=PLAN_KINDS, default=None,
                   help="partition plan kind: pipeline stages or "
                        "filter-parallel shards; default derives from "
                        "the parallel/ axis rules")
    p.add_argument("--link-latency", type=int, default=None,
                   help="cross-device link latency in cycles "
                        "(default: LinkModel default)")
    p.add_argument("--batches", type=int, default=8,
                   help="back-to-back inputs the multi-device makespan "
                        "covers under --simulate (pipeline plans "
                        "overlap them)")
    p.add_argument("-O", "--opt", type=int, default=0, choices=OPT_LEVELS,
                   help="optimization level: 0 = canonical Fig.-3 schedule, "
                        "1 = passes.py pipeline (prefetch reorder, sync "
                        "elision, result-DMA fusion)")
    p.add_argument("--backend", default="golden", choices=sorted(BACKENDS),
                   help="executor backend for --execute (golden = "
                        "contract-checking interpreter, pallas = batched "
                        "fast path)")
    p.add_argument("--format", choices=("summary", "asm", "bin"),
                   default="summary")
    p.add_argument("--simulate", action="store_true",
                   help="also run the event-driven simulator (summary mode)")
    p.add_argument("--execute", action="store_true",
                   help="also execute the program functionally with "
                        "synthetic weights via --backend (summary mode); "
                        "CNN programs run end to end through the spatial "
                        "im2col chain, LM programs layer by layer")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="simulate with the repro.obs tracer and write a "
                        "Chrome trace-event JSON (open in Perfetto; "
                        "summary mode)")
    p.add_argument("--profile", action="store_true",
                   help="render the per-layer/per-core utilization "
                        "report from a traced simulation (summary mode)")
    p.add_argument("-o", "--output", default=None,
                   help="write asm/bin to a file instead of stdout")
    return p


def compile_network(name: str, *, device: str = "XC7Z020", bits_w: int = 4,
                    bits_a: int = 4, ratio: float | None = None,
                    seq_len: int = 64, lut_m: int = 8, lut_n: int = 16,
                    lut_k: int = 128, opt_level: int = 0,
                    devices: int = 1, partition: str | None = None,
                    link_latency: int | None = None,
                    in_hw: int | None = None, width: float | None = None):
    """Programmatic entry point used by the CLI, benchmarks and tests.

    ``devices > 1`` (or an explicit ``partition`` kind) compiles a
    multi-device ``MultiDeviceProgram`` bundle under a plan derived by
    ``partition.derive_plan``; otherwise the legacy single
    ``Program``. ``in_hw``/``width`` scale the CNN workloads to their
    reduced geometry-consistent variants (ignored for LM archs).
    """
    dev = DEVICES[device]
    lut_cfg = LutCoreConfig(m=lut_m, n=lut_n, k=lut_k)
    dsp_cfg = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(dev))
    layers = network_layers(name, seq_len=seq_len, in_hw=in_hw, width=width)
    n_luts = None
    if ratio is not None:
        n_luts = [int(round(ratio * gl.dims.n)) for gl in layers]
    if devices == 1 and partition is None:
        return lower_network(name, layers, lut_cfg, dsp_cfg, dev,
                             bits_w_lut=bits_w, bits_a=bits_a,
                             n_luts=n_luts, opt_level=opt_level)
    link = LinkModel() if link_latency is None \
        else LinkModel(latency_cycles=link_latency)
    plan = derive_plan(layers, devices, kind=partition, link=link)
    return lower_partitioned(name, layers, plan, lut_cfg, dsp_cfg, dev,
                             bits_w_lut=bits_w, bits_a=bits_a,
                             n_luts=n_luts, opt_level=opt_level)


def compile_decode_network(name: str, *, batch: int = 1, max_seq: int = 64,
                           device: str = "XC7Z020", bits_w: int = 4,
                           bits_a: int = 4, ratio: float | None = None,
                           lut_m: int = 8, lut_n: int = 16, lut_k: int = 128,
                           opt_level: int = 0, devices: int = 1,
                           partition: str | None = None,
                           link_latency: int | None = None):
    """Compile the decode-mode step program of an lm/ssm/hybrid arch.

    The emitted program runs one token position for ``batch``
    sequences: weight segments are residency-class ``weights`` (loaded
    by the warm-up invocation, reused by ``lower.steady_program``
    afterwards), attention K/V projections append to ``kv`` cache
    segments sized for ``max_seq`` positions and SSM blocks carry a
    persistent ``state`` segment. ``devices > 1`` compiles the bundle
    via ``lower_partitioned`` and decode-decorates every per-device
    program (``partition.decorate_decode_bundle``).
    """
    from repro.compiler.networks import decode_step_layers
    dev = DEVICES[device]
    lut_cfg = LutCoreConfig(m=lut_m, n=lut_n, k=lut_k)
    dsp_cfg = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(dev))
    layers, spec = decode_step_layers(name, batch=batch, max_seq=max_seq)
    n_luts = None
    if ratio is not None:
        n_luts = [int(round(ratio * gl.dims.n)) for gl in layers]
    if devices == 1 and partition is None:
        return lower_network(f"{name}.decode", layers, lut_cfg, dsp_cfg,
                             dev, bits_w_lut=bits_w, bits_a=bits_a,
                             n_luts=n_luts, opt_level=opt_level, step=spec)
    from repro.compiler.partition import decorate_decode_bundle
    link = LinkModel() if link_latency is None \
        else LinkModel(latency_cycles=link_latency)
    plan = derive_plan(layers, devices, kind=partition, link=link)
    mdp = lower_partitioned(f"{name}.decode", layers, plan, lut_cfg,
                            dsp_cfg, dev, bits_w_lut=bits_w, bits_a=bits_a,
                            n_luts=n_luts, opt_level=opt_level)
    return decorate_decode_bundle(mdp, spec)


def summarize_bundle(mdp, simulate: bool = False, batches: int = 8) -> str:
    """Multi-device summary: plan, per-device programs, hand-offs."""
    lines = [
        f"bundle    {mdp.name}  ({mdp.plan.describe()})",
        f"devices   {mdp.n_devices}  layers {mdp.n_layers} (global)",
        f"edges     {len(mdp.edges)} cross-device channel(s), "
        f"{sum(e.nbytes for e in mdp.edges)} B/traversal over the link",
        f"link      {mdp.plan.link.latency_cycles} cycle latency, "
        f"{mdp.plan.link.bytes_per_cycle} B/cycle",
    ]
    for d, prog in enumerate(mdp.devices):
        s = prog.stats()
        lines.append(f"  dev{d}  {len(prog.layers)} layers, "
                     f"{s.n_instructions} instrs, "
                     f"{s.ddr_footprint} B ddr, "
                     f"{s.bytes_fetched / 1e6:.3f} MB fetched")
    if mdp.devices and mdp.devices[0].opt_stats:
        lines.append("passes    (per device)")
        for ps in mdp.devices[0].opt_stats:
            lines.append(f"  dev0 {ps.render()}")
    if simulate:
        t0 = time.time()
        bs = simulate_program(mdp, batches=batches)
        dt = time.time() - t0
        dev0 = mdp.devices[0].device
        lines.append(
            f"simulated {bs.total_cycles} cycles makespan for "
            f"{bs.batches} input(s) "
            f"({dev0.cycles_to_ms(bs.total_cycles):.3f} ms @ "
            f"{dev0.freq_mhz:.0f} MHz; sim wall {dt:.2f}s)")
        lines.append(f"  latency/traversal {bs.latency_cycles} cycles, "
                     f"steady-state interval {bs.interval_cycles}")
        for d, s in enumerate(bs.device_sims):
            lines.append(f"  dev{d}: {s.total_cycles} cycles")
    return "\n".join(lines)


def summarize(prog, simulate: bool = False) -> str:
    s = prog.stats()
    lines = [
        f"program   {prog.name}  (device {prog.device.name})",
        f"layers    {len(prog.layers)}",
        f"instrs    {s.n_instructions}  "
        + "  ".join(f"{k.lower()}={v}" for k, v in s.by_opcode.items()),
        f"image     {s.image_bytes} B ({s.n_instructions} x 128-bit words)",
        f"ddr map   {len(prog.memory.segments)} segments, "
        f"{s.ddr_footprint} B footprint",
        f"traffic   {s.bytes_fetched / 1e6:.3f} MB fetched, "
        f"{s.bytes_written / 1e6:.3f} MB written back",
    ]
    split = [lp.n_lut / max(lp.dims.n, 1) for lp in prog.layers]
    lines.append(f"lut ratio mean={sum(split) / max(len(split), 1):.3f} "
                 f"min={min(split):.3f} max={max(split):.3f}")
    if prog.opt_stats:
        total_before = prog.opt_stats[0].instrs_before
        total_after = prog.opt_stats[-1].instrs_after
        lines.append(f"passes    {len(prog.opt_stats)} passes, "
                     f"{total_before} -> {total_after} instrs "
                     f"(-{total_before - total_after})")
        for ps in prog.opt_stats:
            lines.append(f"  {ps.render()}")
    if getattr(prog, "step", None) is not None:
        sp = prog.step
        lines.append(f"decode    family={sp.family} batch={sp.batch} "
                     f"max_seq={sp.max_seq} (resident weights + "
                     f"persistent kv/state segments)")
    if simulate:
        t0 = time.time()
        ps = simulate_program(prog)
        dt = time.time() - t0
        lines.append(f"simulated {ps.total_cycles} cycles "
                     f"({prog.device.cycles_to_ms(ps.total_cycles):.3f} ms "
                     f"@ {prog.device.freq_mhz:.0f} MHz; sim wall {dt:.2f}s)")
        if hasattr(ps, "steady_cycles"):
            lines.append(
                f"  decode: warm-up {ps.warmup_cycles} cycles/token, "
                f"steady-state {ps.steady_cycles} cycles/token "
                f"({ps.warmup_cycles / max(ps.steady_cycles, 1):.2f}x "
                f"warm-up cost)")
        for core in ("lut", "dsp"):
            d = ps.decomposition(core)
            lines.append(f"  {core}: wait={d['l_wait']} run={d['l_run']} "
                         f"sig={d['l_sig']} rst={d['l_rst']}")
    return "\n".join(lines)


def execute_report(prog, backend: str = "golden", seed: int = 0) -> str:
    """Execute the program functionally with synthetic weights.

    Conv programs (every layer carries an im2col geometry — the CNN
    workloads) run *end to end*: a synthetic input image is quantized
    to the first layer's activation bits and chained through the whole
    network (im2col staging, depthwise grouped GEMMs, pooling glue,
    shortcut sources, inter-layer requantization). Other programs (the
    LM frontends, whose q/k/v projections fan out rather than chain)
    are driven layer by layer on fresh synthetic activations.

    Accepts a single ``Program`` or a multi-device bundle; the bundle
    path drives the same synthetic weights and activations through
    ``MultiDeviceExecutor``, so its checksum is bit-identical to the
    single-device run of the same network.
    """
    is_bundle = hasattr(prog, "devices")
    step = getattr(prog.devices[0] if is_bundle else prog, "step", None)
    if step is not None:
        return _decode_session_report(prog, backend, seed)
    if is_bundle:
        ex = MultiDeviceExecutor(prog, backend=backend)
        layers = ex.layers
    else:
        ex = get_backend(backend)(prog)
        layers = prog.layers
    rng = np.random.default_rng(seed)
    what = f"{backend} backend" if not is_bundle else \
        f"{backend} backend x{prog.n_devices} devices"
    for lp in layers:
        if is_bundle:
            ex.bind_synthetic(lp.index, seed=seed + lp.index)
        else:
            bind_synthetic(ex, lp, seed=seed + lp.index)

    if layers and all(lp.geometry is not None for lp in layers):
        # whole-CNN inference: quantized synthetic image through the
        # spatial chain
        lp0 = layers[0]
        lo_a, hi_a = qrange(lp0.bits_a)
        x_q = rng.integers(lo_a, hi_a + 1,
                           lp0.geometry.in_shape).astype(np.int8)
        t0 = time.time()
        logits = np.asarray(ex.run(x_q))
        dt = time.time() - t0
        return (f"executed  {len(layers)}/{len(layers)} layers end to "
                f"end via {what} in {dt:.3f}s "
                f"(logits [{logits.shape[0]},{logits.shape[1]}], "
                f"|out| sum {float(np.abs(logits).sum()):.6e})")

    checksum = 0.0
    t0 = time.time()
    for lp in layers:
        lo_a, hi_a = qrange(lp.bits_a)
        shape = (lp.dims.m, lp.dims.k, lp.dims.n) if lp.depthwise \
            else (lp.dims.m, lp.dims.k)
        x_q = rng.integers(lo_a, hi_a + 1, shape).astype(np.int8)
        out = np.asarray(ex.run_layer(lp.index, x_q))
        checksum += float(np.abs(out).sum())
    dt = time.time() - t0
    return (f"executed  {len(layers)}/{len(layers)} layers via "
            f"{what} in {dt:.3f}s (|out| sum {checksum:.6e})")


def _decode_session_report(prog, backend: str = "golden", seed: int = 0,
                           n_tokens: int = 4) -> str:
    """Drive a short greedy decode through an ``ExecutorSession``: bind
    synthetic weights once, then step token by token (warm-up program
    first, steady-state program after)."""
    from repro.compiler.runtime import ExecutorSession
    sess = ExecutorSession(prog, backend=backend)
    sess.bind_synthetic_all(seed=seed if seed else None)
    token, checksum = 1, 0.0
    t0 = time.time()
    for pos in range(n_tokens):
        logits = np.asarray(sess.step(token, pos))
        token = int(np.argmax(logits[0]))
        checksum += float(np.abs(logits).sum())
    dt = time.time() - t0
    return (f"decoded   {n_tokens} token(s) via {backend} session in "
            f"{dt:.3f}s (1 warm-up + {n_tokens - 1} steady step(s), "
            f"|logits| sum {checksum:.6e})")


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list:
        print("\n".join(list_networks()))
        return 0
    if not args.network:
        build_parser().print_usage()
        return 2
    if args.ratio is not None and not 0.0 <= args.ratio <= 1.0:
        print(f"error: --ratio must be in [0, 1], got {args.ratio}",
              file=sys.stderr)
        return 2

    if args.devices < 1:
        print(f"error: --devices must be >= 1, got {args.devices}",
              file=sys.stderr)
        return 2

    try:
        if args.decode:
            prog = compile_decode_network(
                args.network, batch=args.batch, max_seq=args.max_seq,
                device=args.device, bits_w=args.bits_w, bits_a=args.bits_a,
                ratio=args.ratio, lut_m=args.lut_m, lut_n=args.lut_n,
                lut_k=args.lut_k, opt_level=args.opt,
                devices=args.devices, partition=args.partition,
                link_latency=args.link_latency)
        else:
            prog = compile_network(
                args.network, device=args.device, bits_w=args.bits_w,
                bits_a=args.bits_a, ratio=args.ratio, seq_len=args.seq_len,
                lut_m=args.lut_m, lut_n=args.lut_n, lut_k=args.lut_k,
                opt_level=args.opt, devices=args.devices,
                partition=args.partition, link_latency=args.link_latency,
                in_hw=args.in_hw, width=args.width)
    except (KeyError, ValueError, PartitionError) as e:
        msg = e.args[0] if e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return 2

    is_bundle = hasattr(prog, "devices")
    if args.format == "summary":
        if is_bundle:
            print(summarize_bundle(prog, simulate=args.simulate,
                                   batches=args.batches))
        else:
            print(summarize(prog, simulate=args.simulate))
        if args.trace or args.profile:
            from repro.obs import Tracer, profile_report
            tracer = Tracer()
            simulate_program(prog, batches=args.batches, tracer=tracer)
            errs = tracer.counters.closure_errors()
            if errs:
                print("error: cycle accounting failed to close:",
                      file=sys.stderr)
                for e in errs:
                    print(f"  {e}", file=sys.stderr)
                return 1
            if args.trace:
                tracer.save(args.trace)
                n_events = len(tracer.to_chrome()["traceEvents"])
                print(f"trace     {args.trace} ({n_events} events)")
            if args.profile:
                print(profile_report(tracer), end="")
        if args.execute:
            print(execute_report(prog, backend=args.backend))
        return 0
    if args.format == "asm":
        text = asm.disassemble_bundle(prog) if is_bundle \
            else asm.disassemble(prog)
        if args.output:
            with open(args.output, "w") as f:
                f.write(text)
        else:
            sys.stdout.write(text)
        return 0
    blob = asm.to_bundle_binary(prog) if is_bundle else asm.to_binary(prog)
    if args.output:
        with open(args.output, "wb") as f:
            f.write(blob)
    else:
        sys.stdout.buffer.write(blob)
    return 0
