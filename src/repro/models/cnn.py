"""ResNet-18 / MobileNet-V2 in JAX — the paper's evaluation workloads.

Every parametric layer maps 1:1 onto a ``ConvSpec`` in
``repro.core.workloads`` (same names, same order), so the DSE framework
can attach per-layer quantization configs and the FPGA latency model
sees exactly the GEMM the network executes (im2col equivalence).

Quantization-aware forward: with ``quant_cfgs`` given (one
``LayerQuantConfig`` per spec), each conv's filters are fake-quantized
with the paper's hybrid filter-wise scheme (§4: DSP-core filters int4,
LUT-core filters 2–8 bit, KL-based allocation) and activations are
quantized layer-wise — first/last layers at 8 bits, as in the paper.

Normalization is a folded (inference-style) per-channel scale+bias —
trainable, which keeps QAT runs on synthetic data simple and matches
what the accelerator would execute (BN folds into the requantization).

``width``/``in_hw``/``reduced`` knobs build small same-family variants
for CPU smoke tests; ``specs_for`` returns the matching ConvSpec list.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.workloads import ConvSpec, mobilenet_v2_specs, resnet18_specs
from repro.quant.hybrid import LayerQuantConfig, hybrid_fake_quant_weight
from repro.quant.uniform import fake_quant_per_channel, fit_scale, qrange


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    arch: str = "resnet18"              # resnet18 | mobilenet_v2
    n_classes: int = 1000
    in_hw: int = 224
    width: float = 1.0                  # channel multiplier (reduced smoke)
    param_dtype = jnp.float32


def reduced_config(arch: str, n_classes: int = 10) -> CNNConfig:
    return CNNConfig(arch=arch, n_classes=n_classes, in_hw=32, width=0.25)


def _scale_c(c: int, width: float) -> int:
    if width >= 1.0:
        return c
    return max(8, int(round(c * width / 8)) * 8) if c > 8 else c


def specs_for(cfg: CNNConfig) -> list[ConvSpec]:
    """ConvSpec list matching this config (width/input-size scaled).

    Spatial sizes are *propagated* through the layer graph — each
    layer's ``in_hw`` is its producer's (pooled) ``out_hw``, with the
    downsample shortcuts reading the block input three layers back —
    so the scaled specs chain exactly like the full-size network and
    the compiled program's im2col geometry stays executable at any
    input size.
    """
    base = resnet18_specs() if cfg.arch == "resnet18" else mobilenet_v2_specs()
    if cfg.width >= 1.0 and cfg.in_hw == 224 and cfg.n_classes == 1000:
        return base
    out: list[ConvSpec] = []
    for i, s in enumerate(base):
        c_in = 3 if s.is_first else _scale_c(s.c_in, cfg.width)
        c_out = (cfg.n_classes if s.is_last
                 else _scale_c(s.c_out, cfg.width))
        if s.depthwise:
            c_in = c_out = _scale_c(s.c_out, cfg.width)
        if s.is_first:
            in_hw = cfg.in_hw
        else:
            src = out[i - (3 if s.shortcut else 1)]
            in_hw = src.pooled_out_hw
        out.append(dataclasses.replace(s, c_in=c_in, c_out=c_out,
                                       in_hw=in_hw))
    return out


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init(cfg: CNNConfig, rng: jax.Array) -> dict:
    """Params keyed by ConvSpec name: {w, scale, bias}."""
    specs = specs_for(cfg)
    params = {}
    keys = jax.random.split(rng, len(specs))
    for s, k in zip(specs, keys):
        if s.depthwise:
            shape = (s.kernel, s.kernel, 1, s.c_out)
            fan = s.kernel * s.kernel
        else:
            shape = (s.kernel, s.kernel, s.c_in, s.c_out)
            fan = s.kernel * s.kernel * s.c_in
        std = math.sqrt(2.0 / fan)
        params[s.name] = {
            "w": std * jax.random.normal(k, shape, jnp.float32),
            "scale": jnp.ones((s.c_out,), jnp.float32),
            "bias": jnp.zeros((s.c_out,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# Quantized conv primitive
# ---------------------------------------------------------------------------


def _quant_activations(x: jax.Array, bits: int) -> jax.Array:
    s = fit_scale(jax.lax.stop_gradient(x), bits)
    lo, hi = qrange(bits)
    xq = jnp.clip(jnp.round(x / s), lo, hi) * s
    return x + jax.lax.stop_gradient(xq - x)            # STE


def conv2d(x: jax.Array, w: jax.Array, s: ConvSpec) -> jax.Array:
    """The network's raw conv primitive: NHWC x HWIO, ``kernel // 2``
    padding, grouped for depthwise. Also the reference numerics the
    compiler executors' im2col staging is validated against
    (``tests/test_conv_exec.py``)."""
    pad = s.kernel // 2
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(s.stride, s.stride),
        padding=[(pad, pad), (pad, pad)], dimension_numbers=dn,
        feature_group_count=s.c_out if s.depthwise else 1)


def conv_layer(p: dict, x: jax.Array, s: ConvSpec,
               q: LayerQuantConfig | None, relu: bool = True,
               norm: jax.Array | None = None,
               capture: dict | None = None) -> jax.Array:
    """NHWC conv + folded norm + optional relu, with hybrid quant.

    ``norm`` freezes the layer's RMS statistic to a precomputed value
    (inference mode — the batch statistic is data-dependent, so two
    different batches normalize differently; frozen norms are what the
    accelerator folds into its weights). ``capture`` records the
    statistic actually used under ``s.name`` (see
    :func:`calibrate_norms`).
    """
    w = p["w"]
    if q is not None:
        a_bits = 8 if (s.is_first or s.is_last) else q.a_bits
        x = _quant_activations(x, a_bits)
        if s.is_first or s.is_last:
            w = fake_quant_per_channel(w, 8, axis=3)
        else:
            # filters live on the last axis -> move to front for the
            # filter-wise hybrid scheme, then restore.
            w_f = jnp.moveaxis(w, 3, 0)
            w_f = hybrid_fake_quant_weight(w_f, q)
            w = jnp.moveaxis(w_f, 0, 3)
    out = conv2d(x, w, s)
    # BN-style per-channel RMS normalization (mean-free): stabilizes
    # from-scratch QAT; folds into the requantization scale at inference
    # exactly like BN does on the accelerator.
    if norm is None:
        rms = jnp.sqrt(jnp.mean(jnp.square(out), axis=(0, 1, 2),
                                keepdims=True) + 1e-6)
    else:
        rms = jnp.asarray(norm, jnp.float32).reshape(1, 1, 1, -1)
    if capture is not None:
        capture[s.name] = rms.reshape(-1)
    out = (out / rms) * p["scale"] + p["bias"]
    if relu:
        out = jax.nn.relu6(out) if s.depthwise else jax.nn.relu(out)
    return out


def _qc(quant_cfgs, i):
    return None if quant_cfgs is None else quant_cfgs[i]


# ---------------------------------------------------------------------------
# ResNet-18 forward
# ---------------------------------------------------------------------------


def resnet18_forward(params: dict, x: jax.Array, cfg: CNNConfig,
                     quant_cfgs: Sequence[LayerQuantConfig] | None = None,
                     norms: dict | None = None,
                     capture: dict | None = None) -> jax.Array:
    specs = {s.name: s for s in specs_for(cfg)}
    qi = {s.name: i for i, s in enumerate(specs_for(cfg))}

    def conv(name, x, relu=True):
        return conv_layer(params[name], x, specs[name],
                          _qc(quant_cfgs, qi[name]), relu,
                          norm=None if norms is None else norms[name],
                          capture=capture)

    x = conv("conv1", x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")

    def basic_block(x, a, b, ds=None):
        h = conv(a, x)
        h = conv(b, h, relu=False)
        sc = x if ds is None else conv(ds, x, relu=False)
        return jax.nn.relu(h + sc)

    x = basic_block(x, "conv2", "conv3")
    x = basic_block(x, "conv4", "conv5")
    x = basic_block(x, "conv6", "conv7", "conv8_ds")
    x = basic_block(x, "conv9", "conv10")
    x = basic_block(x, "conv11", "conv12", "conv13_ds")
    x = basic_block(x, "conv14", "conv15")
    x = basic_block(x, "conv16", "conv17", "conv18_ds")
    x = basic_block(x, "conv19", "conv20")

    x = jnp.mean(x, axis=(1, 2), keepdims=True)          # GAP -> [B,1,1,C]
    x = conv("fc", x, relu=False)
    return x[:, 0, 0, :]


# ---------------------------------------------------------------------------
# MobileNet-V2 forward
# ---------------------------------------------------------------------------


def mobilenet_v2_forward(params: dict, x: jax.Array, cfg: CNNConfig,
                         quant_cfgs: Sequence[LayerQuantConfig] | None = None,
                         norms: dict | None = None,
                         capture: dict | None = None) -> jax.Array:
    all_specs = specs_for(cfg)
    specs = {s.name: s for s in all_specs}
    qi = {s.name: i for i, s in enumerate(all_specs)}

    def conv(name, x, relu=True):
        return conv_layer(params[name], x, specs[name],
                          _qc(quant_cfgs, qi[name]), relu,
                          norm=None if norms is None else norms[name],
                          capture=capture)

    x = conv("conv0", x)
    x = conv("b0_dw", x)
    x = conv("b0_pw", x, relu=False)

    bi = 1
    while f"b{bi}_exp" in specs:
        inp = x
        h = conv(f"b{bi}_exp", x)
        h = conv(f"b{bi}_dw", h)
        h = conv(f"b{bi}_pw", h, relu=False)
        if h.shape == inp.shape:
            h = h + inp                                   # inverted residual
        x = h
        bi += 1

    x = conv("conv_last", x)
    x = jnp.mean(x, axis=(1, 2), keepdims=True)
    x = conv("fc", x, relu=False)
    return x[:, 0, 0, :]


def forward(params: dict, x: jax.Array, cfg: CNNConfig,
            quant_cfgs: Sequence[LayerQuantConfig] | None = None,
            norms: dict | None = None,
            capture: dict | None = None) -> jax.Array:
    if cfg.arch == "resnet18":
        return resnet18_forward(params, x, cfg, quant_cfgs, norms, capture)
    if cfg.arch == "mobilenet_v2":
        return mobilenet_v2_forward(params, x, cfg, quant_cfgs, norms,
                                    capture)
    raise ValueError(f"unknown CNN arch {cfg.arch!r}")


# ---------------------------------------------------------------------------
# Inference-mode norm freezing + weight folding
# ---------------------------------------------------------------------------


def calibrate_norms(params: dict, x: jax.Array, cfg: CNNConfig) -> dict:
    """Freeze every layer's data-dependent RMS statistic on one
    calibration batch: ``{name: rms[c_out]}``.

    The batch statistic makes the forward a function of the *batch*,
    not the sample — two batches normalize differently, so dataset
    evaluation (and the accelerator, whose programs have no norm op)
    needs the statistic pinned. Evaluate with
    ``forward(..., norms=calibrate_norms(...))``.
    """
    capture: dict = {}
    forward(params, x, cfg, capture=capture)
    return capture


def fold_inference_weights(params: dict, cfg: CNNConfig,
                           norms: dict) -> dict:
    """Fold the frozen per-channel norm into effective conv weights:
    ``w_eff[..., c] = w[..., c] * scale[c] / rms[c]`` — exactly the
    BN-fold the accelerator deploys, so a compiled program binding
    quantized ``w_eff`` reproduces the frozen-norm network with no
    norm op in the instruction stream.

    Requires ``bias == 0`` everywhere (the compiled GEMM+elementwise
    pipeline has no bias stage to fold a nonzero bias into).
    """
    folded = {}
    for s in specs_for(cfg):
        p = params[s.name]
        if float(jnp.max(jnp.abs(p["bias"]))) != 0.0:
            raise ValueError(
                f"layer {s.name} has a nonzero norm bias; the compiled "
                f"pipeline has no bias stage to fold it into")
        gain = (p["scale"] / jnp.asarray(norms[s.name], jnp.float32)
                ).reshape(1, 1, 1, -1)
        folded[s.name] = p["w"] * gain
    return folded


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
