"""Decoder-only LM covering the dense / MoE / MLA / VLM families.

One parameterized implementation serves yi-34b, gemma-7b, llama3.2-1b,
qwen3-8b, qwen3-moe-235b, deepseek-v2-236b and qwen2-vl-2b. Layers are
stacked and scanned (``jax.lax.scan``) so the HLO stays bounded for
94-layer models; an optional dense prefix (deepseek's first dense layer)
is unrolled before the scanned MoE stack.

The paper's technique (HeteroLinear hybrid quantization) is a
first-class config: with ``hetero_quant`` set, every attention/MLP
projection runs the QAT fake-quant forward of §4 (per-column bit-width
by core assignment, layer-wise activation quantization); the serving
path can deploy the same weights through the integer Pallas kernels.

Entry points:
  param_specs / init / abstract          — parameters
  forward(params, tokens, ...)           — causal logits (train, prefill)
  init_cache / decode_step               — KV-cache decoding (MLA uses the
                                           compressed-cache absorbed form)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamSpec
from repro.parallel.sharding import AxisRules, DEFAULT_RULES, with_logical_constraint
from repro.quant.hybrid import LayerQuantConfig
from repro.quant.uniform import fit_scale, qrange


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class HeteroQuantConfig:
    """Paper §4/§5 knobs applied to every projection of the LM."""
    w_bits_lut: int = 4
    a_bits: int = 4
    ratio: float = 0.5         # columns on the flexible (bitplane) path

    def layer_cfg(self) -> LayerQuantConfig:
        return LayerQuantConfig(w_bits_lut=self.w_bits_lut,
                                a_bits=self.a_bits, ratio=self.ratio)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    vocab_pad_multiple: int = 256
    rope_theta: float = 10000.0
    qk_norm: bool = False                 # qwen3
    act: str = "silu"                     # gemma: "gelu" (GeGLU)
    moe: L.MoEConfig | None = None
    n_dense_prefix: int = 0               # deepseek: 1 dense layer first
    d_ff_dense: int | None = None         # ff of the dense-prefix layers
    mla: MLAConfig | None = None
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl
    tie_embeddings: bool = False          # gemma / llama3.2 / qwen2-vl
    hetero_quant: HeteroQuantConfig | None = None
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: str = "none"                   # none | full | dots
    scan_unroll: bool = False             # full unroll (dry-run flops acct)
    kv_cache_quant: bool = False          # int8 KV cache (per-head scales)
    dense_attn_max: int = 8192            # dense softmax below, blockwise above
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def qk_dim(self) -> int:
        if self.mla:
            return self.mla.qk_nope_dim + self.mla.qk_rope_dim
        return self.head_dim

    @property
    def v_head_dim(self) -> int:
        return self.mla.v_dim if self.mla else self.head_dim


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: LMConfig) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    if cfg.mla:
        a = cfg.mla
        h = cfg.n_heads
        return {
            "wq_a": ParamSpec((d, a.q_lora), ("embed", None), dt),
            "q_norm": L.rmsnorm_spec(a.q_lora, dt),
            "wq_b": ParamSpec((a.q_lora, h * (a.qk_nope_dim + a.qk_rope_dim)),
                              (None, "heads"), dt, fan_in=a.q_lora),
            "wkv_a": ParamSpec((d, a.kv_lora + a.qk_rope_dim),
                               ("embed", None), dt),
            "kv_norm": L.rmsnorm_spec(a.kv_lora, dt),
            "wkv_b": ParamSpec((a.kv_lora, h * (a.qk_nope_dim + a.v_dim)),
                               (None, "heads"), dt, fan_in=a.kv_lora),
            "wo": ParamSpec((h * a.v_dim, d), ("heads", "embed"), dt),
        }
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, hq * hd), ("embed", "heads"), dt),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), dt),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), dt),
        "wo": ParamSpec((hq * hd, d), ("heads", "embed"), dt),
    }
    if cfg.qk_norm:
        specs["q_norm"] = L.rmsnorm_spec(hd, dt)
        specs["k_norm"] = L.rmsnorm_spec(hd, dt)
    return specs


def _layer_specs(cfg: LMConfig, moe_layer: bool) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    specs = {
        "ln_attn": L.rmsnorm_spec(d, dt),
        "attn": _attn_specs(cfg),
        "ln_mlp": L.rmsnorm_spec(d, dt),
    }
    if moe_layer and cfg.moe is not None:
        specs["moe"] = L.moe_specs(d, cfg.moe, dt)
    else:
        specs["mlp"] = L.mlp_specs(d, cfg.d_ff_dense or cfg.d_ff, dt)
    return specs


def param_specs(cfg: LMConfig) -> dict:
    dt = cfg.param_dtype
    n_scan = cfg.n_layers - cfg.n_dense_prefix
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), dt, "embed"),
        "layers": L.stack_specs(_layer_specs(cfg, moe_layer=True), n_scan),
        "ln_f": L.rmsnorm_spec(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), dt)
    if cfg.n_dense_prefix:
        specs["dense_prefix"] = [
            _layer_specs(cfg, moe_layer=False)
            for _ in range(cfg.n_dense_prefix)]
    return specs


def init(cfg: LMConfig, rng: jax.Array) -> dict:
    return L.init_params(param_specs(cfg), rng)


def abstract(cfg: LMConfig) -> dict:
    return L.abstract_params(param_specs(cfg))


def param_axes(cfg: LMConfig) -> dict:
    return L.param_axes_tree(param_specs(cfg))


def param_count(cfg: LMConfig) -> int:
    return L.param_count(param_specs(cfg))


def active_param_count(cfg: LMConfig) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    if cfg.moe is None:
        return param_count(cfg)
    total = param_count(cfg)
    n_scan = cfg.n_layers - cfg.n_dense_prefix
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    expert_params = 3 * cfg.d_model * cfg.moe.d_ff     # gate/up/down
    total -= n_scan * (e - k) * expert_params
    return total


# ---------------------------------------------------------------------------
# Quantized / plain projection
# ---------------------------------------------------------------------------


def _proj(x: jax.Array, w: jax.Array, cfg: LMConfig) -> jax.Array:
    """Projection with optional hybrid fake-quant (paper §4, QAT form)."""
    hq = cfg.hetero_quant
    if hq is None:
        return x @ w
    out = w.shape[-1]
    n_serial = int(round(hq.ratio * out))
    # Column split without data-dependent permutation (the KL allocation
    # is applied at deploy time; under scan the boundary must be static).
    is_serial = jnp.arange(out) < n_serial

    def fq_w(w, bits):
        lim = jnp.max(jnp.abs(w), axis=0, keepdims=True)
        s = jnp.maximum(lim.astype(jnp.float32), 1e-8) / (2 ** (bits - 1) - 1)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / s),
                     -(2 ** (bits - 1)), 2 ** (bits - 1) - 1) * s
        return (w + jax.lax.stop_gradient(q.astype(w.dtype) - w))

    w_q = jnp.where(is_serial[None, :], fq_w(w, hq.w_bits_lut),
                    fq_w(w, 4))
    s_a = fit_scale(jax.lax.stop_gradient(x).astype(jnp.float32), hq.a_bits)
    lo, hi = qrange(hq.a_bits)
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / s_a), lo, hi) * s_a
    x_q = x + jax.lax.stop_gradient(x_q.astype(x.dtype) - x)
    return x_q @ w_q


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _attention(p: dict, x: jax.Array, positions: jax.Array, cfg: LMConfig,
               rules: AxisRules, cache: dict | None = None,
               cache_len: jax.Array | int | None = None
               ) -> tuple[jax.Array, dict | None]:
    """Self-attention (full causal when cache is None, else one decode
    step writing at ``cache_len``). Returns (out, updated_cache)."""
    if cfg.mla:
        return _mla_attention(p, x, positions, cfg, rules, cache, cache_len)
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = _proj(x, p["wq"], cfg).reshape(b, s, hq, hd)
    k = _proj(x, p["wk"], cfg).reshape(b, s, hkv, hd)
    v = _proj(x, p["wv"], cfg).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = L.apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    q = with_logical_constraint(
        q, ("batch", "act_seq_attn", "act_heads", None), rules=rules)

    if cache is None:
        k = with_logical_constraint(
            k, ("batch", "act_seq_attn", "act_kv_heads", None), rules=rules)
        if s <= cfg.dense_attn_max:
            out = L.dense_attention(q, k, v, causal=True)
        else:
            out = L.blockwise_attention(q, k, v, causal=True,
                                        q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk)
        new_cache = None
    else:
        idx = jnp.asarray(cache_len, jnp.int32)
        quant = cfg.kv_cache_quant
        if quant:
            if s > 1:  # prefill calibrates the per-head scales
                k_sc, v_sc = L.kv_scale_from(k), L.kv_scale_from(v)
            else:      # decode clips into the prefill-calibrated scales
                k_sc, v_sc = cache["k_scale"], cache["v_scale"]
            k_store = L.quantize_kv(k, k_sc)
            v_store = L.quantize_kv(v, v_sc)
        else:
            k_sc = v_sc = None
            k_store, v_store = k, v
        k_cache = L.cache_write(cache["k"], k_store, idx)
        v_cache = L.cache_write(cache["v"], v_store, idx)
        k_cache = with_logical_constraint(
            k_cache, ("batch", "kv_seq", "act_kv_heads", None), rules=rules)
        v_cache = with_logical_constraint(
            v_cache, ("batch", "kv_seq", "act_kv_heads", None), rules=rules)
        if s == 1:
            out = L.decode_attention(q, k_cache, v_cache, kv_len=idx + s,
                                     k_scale=k_sc, v_scale=v_sc)
        else:
            # prefill: attend within the freshly written prompt
            out = L.blockwise_attention(q, k, v, causal=True,
                                        q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk,
                                        kv_offset=0)
        new_cache = {"k": k_cache, "v": v_cache}
        if quant:
            new_cache["k_scale"] = k_sc
            new_cache["v_scale"] = v_sc

    out = with_logical_constraint(
        out, ("batch", "act_seq_attn", "act_heads", None), rules=rules)
    out = out.reshape(b, s, hq * hd)
    return _proj(out, p["wo"], cfg), new_cache


def _mla_attention(p: dict, x: jax.Array, positions: jax.Array,
                   cfg: LMConfig, rules: AxisRules,
                   cache: dict | None, cache_len) -> tuple[jax.Array, dict | None]:
    """DeepSeek-V2 MLA. Full form for train/prefill; absorbed compressed-
    cache form for decode (the cache holds only [B, S, kv_lora + rope])."""
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5

    q = _proj(L.rmsnorm(_proj(x, p["wq_a"], cfg), p["q_norm"], cfg.norm_eps),
              p["wq_b"], cfg).reshape(b, s, h, a.qk_nope_dim + a.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = _proj(x, p["wkv_a"], cfg)                        # [B,S,lora+rope]
    c, k_rope = jnp.split(ckv, [a.kv_lora], axis=-1)
    c = L.rmsnorm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    if cache is None:
        kv = (c @ p["wkv_b"]).reshape(b, s, h, a.qk_nope_dim + a.v_dim)
        k_nope, v = jnp.split(kv, [a.qk_nope_dim], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, a.qk_rope_dim))],
            axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qf = with_logical_constraint(
            qf, ("batch", "act_seq_attn", "act_heads", None), rules=rules)
        out = L.blockwise_attention(qf, k, v, causal=True,
                                    q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk,
                                    softmax_scale=scale)
        new_cache = None
    elif s > 1:
        # Prefill: write the compressed cache, attend within the prompt.
        idx = jnp.asarray(cache_len, jnp.int32)
        c_cache = L.cache_write(cache["c"], c, idx)
        r_cache = L.cache_write(cache["k_rope"], k_rope[:, :, 0, :], idx)
        kv = (c @ p["wkv_b"]).reshape(b, s, h, a.qk_nope_dim + a.v_dim)
        k_nope, v = jnp.split(kv, [a.qk_nope_dim], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, a.qk_rope_dim))],
            axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = L.blockwise_attention(qf, k, v, causal=True,
                                    q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk,
                                    softmax_scale=scale)
        new_cache = {"c": c_cache, "k_rope": r_cache}
    else:
        # Absorbed decode: score and read directly in the compressed space.
        idx = jnp.asarray(cache_len, jnp.int32)
        c_cache = L.cache_write(cache["c"], c, idx)
        r_cache = L.cache_write(cache["k_rope"], k_rope[:, :, 0, :], idx)
        c_cache = with_logical_constraint(
            c_cache, ("batch", "kv_seq", None), rules=rules)
        wkv_b = p["wkv_b"].reshape(a.kv_lora, h, a.qk_nope_dim + a.v_dim)
        wk, wv = jnp.split(wkv_b, [a.qk_nope_dim], axis=-1)
        q_c = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                         wk.astype(jnp.float32))           # [B,1,H,lora]
        s_c = jnp.einsum("bqhc,bkc->bhqk", q_c,
                         c_cache.astype(jnp.float32))
        s_r = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                         r_cache.astype(jnp.float32))
        logits = (s_c + s_r) * scale
        skv = c_cache.shape[1]
        mask = jnp.arange(skv)[None] < (idx + s)
        logits = jnp.where(mask[:, None, None], logits, L.NEG_INF)
        pattn = jax.nn.softmax(logits, axis=-1)
        o_c = jnp.einsum("bhqk,bkc->bqhc", pattn,
                         c_cache.astype(jnp.float32))      # [B,1,H,lora]
        out = jnp.einsum("bqhc,chd->bqhd", o_c, wv.astype(jnp.float32))
        out = out.astype(x.dtype)
        new_cache = {"c": c_cache, "k_rope": r_cache}

    out = out.reshape(b, s, h * a.v_dim)
    return _proj(out, p["wo"], cfg), new_cache


# ---------------------------------------------------------------------------
# Layer body + full forward
# ---------------------------------------------------------------------------


def _layer_apply(p: dict, x: jax.Array, positions: jax.Array, cfg: LMConfig,
                 rules: AxisRules, moe_layer: bool,
                 cache: dict | None = None, cache_len=None
                 ) -> tuple[jax.Array, jax.Array, dict | None]:
    """Pre-norm block. Returns (x, aux_loss, new_cache)."""
    h_attn, new_cache = _attention(p["attn"], L.rmsnorm(x, p["ln_attn"],
                                                        cfg.norm_eps),
                                   positions, cfg, rules, cache, cache_len)
    x = x + h_attn
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)
    h_norm = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    if moe_layer and cfg.moe is not None:
        h_ffn, aux = L.moe_apply(p["moe"], h_norm, cfg.moe, cfg.act, rules)
    else:
        h_ffn, aux = L.mlp_apply(p["mlp"], h_norm, cfg.act, rules), 0.0
    x = x + h_ffn
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)
    return x, jnp.asarray(aux, jnp.float32), new_cache


def _remat_wrap(fn, cfg: LMConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def forward(params: dict, tokens: jax.Array, cfg: LMConfig,
            rules: AxisRules = DEFAULT_RULES,
            positions: jax.Array | None = None,
            extra_embed: jax.Array | None = None,
            last_only: bool = False,
            slice_vocab: bool = True) -> tuple[jax.Array, jax.Array]:
    """Causal logits for train/prefill. tokens: [B, S] int32.

    ``slice_vocab=False`` returns the PADDED-vocab logits — slicing a
    GSPMD-sharded vocab dim forces a full-logits all-gather (67 GB/step
    measured on gemma train_4k); the loss path masks instead.

    ``extra_embed`` (VLM/audio frontends): [B, S, d_model] added to the
    token embedding (precomputed patch/frame embeddings, stubbed per the
    task spec). Returns (logits [B, S, vocab], aux_loss).
    """
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]                          # [B, S, M]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)

    for p_dense in params.get("dense_prefix", []):
        def dense_body(x, p=p_dense):
            y, _, _ = _layer_apply(p, x, positions, cfg, rules,
                                   moe_layer=False)
            return y
        x = _remat_wrap(dense_body, cfg)(x)

    def scan_body(carry, p_layer):
        x, aux = carry
        def body(x):
            return _layer_apply(p_layer, x, positions, cfg, rules,
                                moe_layer=True)[:2]
        y, aux_i = _remat_wrap(body, cfg)(x)
        return (y, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.float32(0.0)),
                               params["layers"], unroll=cfg.scan_unroll)

    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = (x @ unembed).astype(jnp.float32)
    logits = with_logical_constraint(logits, ("batch", None, "vocab_act"),
                                     rules=rules)
    if not slice_vocab:
        return logits, aux
    return logits[..., :cfg.vocab], aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: LMConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16) -> dict:
    n_scan = cfg.n_layers - cfg.n_dense_prefix
    if cfg.mla:
        a = cfg.mla
        layer = {
            "c": ParamSpec((batch, max_seq, a.kv_lora),
                           ("batch", "kv_seq", None), dtype, "zeros"),
            "k_rope": ParamSpec((batch, max_seq, a.qk_rope_dim),
                                ("batch", "kv_seq", None), dtype, "zeros"),
        }
    else:
        kv_dt = jnp.int8 if cfg.kv_cache_quant else dtype
        layer = {
            "k": ParamSpec((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           kv_dt, "zeros"),
            "v": ParamSpec((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           kv_dt, "zeros"),
        }
        if cfg.kv_cache_quant:
            layer["k_scale"] = ParamSpec((batch, cfg.n_kv_heads),
                                         ("batch", "act_kv_heads"),
                                         jnp.float32, "ones")
            layer["v_scale"] = ParamSpec((batch, cfg.n_kv_heads),
                                         ("batch", "act_kv_heads"),
                                         jnp.float32, "ones")
    specs = {"layers": L.stack_specs(layer, n_scan)}
    if cfg.n_dense_prefix:
        specs["dense_prefix"] = [dict(layer) for _ in range(cfg.n_dense_prefix)]
    return specs


def init_cache(cfg: LMConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return L.init_params(cache_specs(cfg, batch, max_seq, dtype), jax.random.key(0))


def prefill(params: dict, tokens: jax.Array, cache: dict, cfg: LMConfig,
            rules: AxisRules = DEFAULT_RULES,
            extra_embed: jax.Array | None = None,
            last_only: bool = False) -> tuple[jax.Array, dict]:
    """Score the prompt AND fill the KV cache (positions [0, S)).

    Returns (logits [B, S, vocab], cache). Subsequent ``decode_step``
    calls continue from cache_len = S.
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)

    new_cache: dict[str, Any] = {}
    if cfg.n_dense_prefix:
        new_cache["dense_prefix"] = []
        for p_dense, c_dense in zip(params["dense_prefix"],
                                    cache["dense_prefix"]):
            x, _, c_new = _layer_apply(p_dense, x, positions, cfg, rules,
                                       moe_layer=False, cache=c_dense,
                                       cache_len=0)
            new_cache["dense_prefix"].append(c_new)

    def scan_body(x, xs):
        p_layer, c_layer = xs
        y, _, c_new = _layer_apply(p_layer, x, positions, cfg, rules,
                                   moe_layer=True, cache=c_layer,
                                   cache_len=0)
        return y, c_new

    x, cache_layers = jax.lax.scan(scan_body, x,
                                   (params["layers"], cache["layers"]),
                                   unroll=cfg.scan_unroll)
    new_cache["layers"] = cache_layers

    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = (x @ unembed).astype(jnp.float32)
    return logits[..., :cfg.vocab], new_cache


def decode_step(params: dict, token: jax.Array, cache: dict,
                cache_len: jax.Array | int, cfg: LMConfig,
                rules: AxisRules = DEFAULT_RULES,
                extra_embed: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
    """One decode step. token: [B, 1] int32; returns (logits [B, vocab],
    updated cache). ``cache_len`` is the number of valid positions."""
    b = token.shape[0]
    idx = jnp.asarray(cache_len, jnp.int32)
    positions = jnp.broadcast_to(idx.reshape(-1, 1), (b, 1)).astype(jnp.int32)
    x = params["embed"][token]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)

    new_cache: dict[str, Any] = {}
    if cfg.n_dense_prefix:
        new_cache["dense_prefix"] = []
        for p_dense, c_dense in zip(params["dense_prefix"],
                                    cache["dense_prefix"]):
            x, _, c_new = _layer_apply(p_dense, x, positions, cfg, rules,
                                       moe_layer=False, cache=c_dense,
                                       cache_len=idx)
            new_cache["dense_prefix"].append(c_new)

    def scan_body(x, xs):
        p_layer, c_layer = xs
        y, _, c_new = _layer_apply(p_layer, x, positions, cfg, rules,
                                   moe_layer=True, cache=c_layer,
                                   cache_len=idx)
        return y, c_new

    x, cache_layers = jax.lax.scan(scan_body, x,
                                   (params["layers"], cache["layers"]),
                                   unroll=cfg.scan_unroll)
    new_cache["layers"] = cache_layers

    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = (x[:, 0] @ unembed).astype(jnp.float32)
    return logits[..., :cfg.vocab], new_cache
