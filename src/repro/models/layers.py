"""Shared model building blocks with logical-axis sharding annotations.

Parameter trees are declared as ``ParamSpec`` pytrees (shape + logical
axes + init law); generic helpers materialize them (``init_params``),
build abstract stand-ins for the dry-run (``abstract_params``), or
extract the logical-axes tree for the sharding rules
(``param_axes_tree``). Layer-stacked parameters carry a leading
"layers" axis and are consumed by ``jax.lax.scan``.

Activation sharding is expressed with ``with_logical_constraint`` using
these activation axis names (per-arch rule overrides rebind them):

  batch          -> ("pod", "data")      always
  act_heads      -> ("model",)           attention heads (divisible archs)
  act_kv_heads   -> ("model",)           KV heads (falls back if < mesh)
  act_seq_attn   -> ()                   q-sequence inside attention; bound
                                         to ("model",) for archs whose head
                                         count does not divide the mesh
                                         (sequence/context parallelism)
  act_mlp        -> ("model",)           MLP hidden
  kv_seq         -> ()                   KV-cache sequence; bound to
                                         ("data","model") for long-context
  expert_group   -> ("data",)            MoE dispatch groups
  act_experts    -> ("model",)           MoE expert dim of dispatched acts
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import AxisRules, DEFAULT_RULES, with_logical_constraint


# ---------------------------------------------------------------------------
# ParamSpec machinery
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter leaf."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed
    fan_in: int | None = None     # for "normal": std = 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank "
                             "mismatch")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree: Any, rng: jax.Array) -> Any:
    """Materialize a ParamSpec tree into arrays."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    rngs = jax.random.split(rng, max(len(leaves), 1))

    def make(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        if spec.init == "embed":
            return (jax.random.normal(key, spec.shape, jnp.float32)
                    .astype(spec.dtype))
        fan = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2
                              else spec.shape[-1])
        std = 1.0 / math.sqrt(max(fan, 1))
        return (std * jax.random.normal(key, spec.shape, jnp.float32)
                ).astype(spec.dtype)

    return jax.tree.unflatten(
        treedef, [make(s, k) for s, k in zip(leaves, rngs)])


def abstract_params(spec_tree: Any) -> Any:
    """ShapeDtypeStruct stand-ins (no allocation) for the dry-run."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        spec_tree, is_leaf=_is_spec)


def param_axes_tree(spec_tree: Any) -> Any:
    """Logical-axes tree congruent with the params (for sharding rules)."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def param_count(spec_tree: Any) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec))


def stack_specs(spec_tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Prefix every leaf with a stacked layer dimension (for lax.scan)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes,
                            s.dtype, s.init, s.fan_in),
        spec_tree, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int, dtype=jnp.bfloat16) -> ParamSpec:
    return ParamSpec((dim,), (None,), dtype, "ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # [head_dim//2]


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the frequency bands of each head are
    split into ``sections`` (t, h, w) groups, each rotated by its own
    position component. positions: [3, B, S]. With all three components
    equal (text-only) this reduces exactly to standard RoPE.
    """
    d = x.shape[-1]
    if sum(sections) != d // 2:
        raise ValueError(f"sections {sections} must sum to head_dim/2={d // 2}")
    freqs = rope_frequencies(d, theta)                     # [d/2]
    # Select, per frequency band, which position component drives it.
    comp = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    picked = jnp.moveaxis(positions.astype(jnp.float32), 0, -1)  # [B, S, 3]
    ang = picked[..., comp] * freqs                        # [B, S, d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (blockwise online-softmax; pure JAX, compiles everywhere)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, q_chunk: int = 512,
                        kv_chunk: int = 1024, kv_offset: int = 0,
                        softmax_scale: float | None = None) -> jax.Array:
    """Memory-efficient attention: online softmax over KV chunks.

    q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D], Hq % Hkv == 0. Never
    materializes more than [B, Hq, q_chunk, kv_chunk] of scores — the
    pure-JAX flash schedule (same math as kernels/flash_attention.py).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]                     # may differ from d (MLA)
    rep = hq // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # Pad seq dims to chunk multiples (masked out below).
    pq = (-sq) % q_chunk
    pk = (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // q_chunk, (skv + pk) // kv_chunk

    # NOTES: the query head axis stays whole (never grouped into
    # [hkv, rep]) so GSPMD head sharding survives; KV heads are repeated
    # *per chunk* inside the scan — a [B, kv_chunk, Hq, D] transient.
    # Tensors stay in the model dtype (bf16) end to end — casting q/k/v
    # to fp32 up front doubled every attention reshard (measured on the
    # collective-bound dry-run cells); fp32 lives only in the softmax
    # statistics and the accumulator via preferred_element_type.
    qc = q.reshape(b, nq, q_chunk, hq, d)
    kc = k.reshape(b, nk, kv_chunk, hkv, d)
    vc = v.reshape(b, nk, kv_chunk, hkv, dv)

    q_pos_base = jnp.arange(nq) * q_chunk
    k_pos_base = jnp.arange(nk) * kv_chunk

    def per_q_chunk(args):
        qi, qbase = args                                  # [B, qc, Hq, D]

        def kv_step(carry, kv):
            m, l, acc = carry
            kj, vj, kbase = kv
            if rep > 1:
                kj = jnp.repeat(kj, rep, axis=2)          # [B, kc, Hq, D]
                vj = jnp.repeat(vj, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            qpos = qbase + jnp.arange(q_chunk) + kv_offset
            kpos = kbase + jnp.arange(kv_chunk)
            mask = kpos[None, :] < skv                     # padding mask
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hq, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), k_pos_base))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bhqd->bqhd", out)               # [B, qc, Hq, D]

    outs = jax.lax.map(per_q_chunk, (jnp.moveaxis(qc, 1, 0), q_pos_base))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, hq, dv)
    return out[:, :sq].astype(v.dtype)


def quantize_kv(x: jax.Array, scale: jax.Array) -> jax.Array:
    """[B, S, H, D] -> int8 with per-(batch, head) ``scale`` [B, H]."""
    s = scale[:, None, :, None]
    return jnp.clip(jnp.round(x.astype(jnp.float32) / jnp.maximum(s, 1e-8)),
                    -127, 127).astype(jnp.int8)


def kv_scale_from(x: jax.Array) -> jax.Array:
    """Prefill-calibrated per-(batch, head) int8 scale: max|x|/127."""
    return (jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3))
            / 127.0 + 1e-8)


def cache_write(cache: jax.Array, new: jax.Array, idx,
                axis: int = 1) -> jax.Array:
    """Write ``new`` into ``cache`` at position ``idx`` along ``axis``.

    Uses a one-hot masked blend instead of ``dynamic_update_slice``:
    a DUS at a *traced* index into a dimension sharded by GSPMD forces
    an involuntary all-gather of the whole cache every layer (measured:
    ~60x the bytes on decode_32k); the masked blend is elementwise, so
    each shard updates locally. For a full-length write (prefill with
    S == max_seq) the new values replace the cache outright.
    """
    s_cache = cache.shape[axis]
    s_new = new.shape[axis]
    new = new.astype(cache.dtype)
    if s_new == s_cache:
        return new
    if s_new > 1:
        # prefill into a longer cache: pad to length (cache assumed
        # empty beyond idx; positions outside the prompt stay zero)
        pads = [(0, 0)] * cache.ndim
        pads[axis] = (0, s_cache - s_new)
        return jnp.pad(new, pads)
    shape = [1] * cache.ndim
    shape[axis] = s_cache
    mask = (jnp.arange(s_cache) == jnp.asarray(idx, jnp.int32)
            ).reshape(shape)
    return jnp.where(mask, new, cache)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, kv_offset: int = 0,
                    softmax_scale: float | None = None) -> jax.Array:
    """Full-softmax attention in one einsum pair (no scan).

    For short sequences (train_4k) this beats the blockwise form under
    GSPMD: sharding propagates cleanly through straight-line einsums,
    while while-loop boundaries made GSPMD all-gather q/k/v chunks
    (measured on the collective-bound dry-run cells). Memory is
    O(S^2 / heads-shards) — use blockwise beyond ~8k tokens.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    rep = hq // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + kv_offset
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array | int, *,
                     softmax_scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None) -> jax.Array:
    """Single-token attention over a (possibly partially filled) cache.

    q: [B, 1, Hq, D]; caches: [B, Skv, Hkv, D]; kv_len: valid prefix.
    ``k_scale``/``v_scale`` ([B, Hkv], fp32): per-head dequantization
    scales for an int8 cache — they factor out of both contractions
    exactly, so the int8 values feed the MXU directly and HBM reads
    stay at 1 byte/element (the decode step's dominant traffic).
    """
    b, _, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    rep = hq // hkv
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    int8_cache = k_cache.dtype == jnp.int8
    qr = q.reshape(b, hkv, rep, d)
    qk_dtype = jnp.bfloat16 if int8_cache else k_cache.dtype
    s = jnp.einsum("bhrd,bkhd->bhrk", qr.astype(qk_dtype),
                   k_cache.astype(qk_dtype) if int8_cache else k_cache,
                   preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        s = s * k_scale[:, :, None, None]
    mask = jnp.arange(skv)[None] < jnp.asarray(kv_len).reshape(-1, 1)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv_dtype = jnp.bfloat16 if int8_cache else v_cache.dtype
    out = jnp.einsum("bhrk,bkhd->bhrd", p.astype(pv_dtype),
                     v_cache.astype(pv_dtype) if int8_cache else v_cache,
                     preferred_element_type=jnp.float32)
    if v_scale is not None:
        out = out * v_scale[:, :, None, None]
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_specs(d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    return {
        "gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "down": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype),
    }


def mlp_apply(p: dict, x: jax.Array, act: str = "silu",
              rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    h = ACTIVATIONS[act](x @ p["gate"]) * (x @ p["up"])
    h = with_logical_constraint(h, ("batch", None, "act_mlp"), rules=rules)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped dispatch, token dropping)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden
    n_shared: int = 0               # shared (always-on) experts
    capacity_factor: float = 1.25
    group_size: int = 512           # tokens per dispatch group
    router_z_loss: float = 1e-3


def moe_specs(d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    specs = {
        "router": ParamSpec((d_model, cfg.n_experts), ("embed", None),
                            jnp.float32, fan_in=d_model),
        "gate": ParamSpec((cfg.n_experts, d_model, cfg.d_ff),
                          ("experts", "embed", None), dtype, fan_in=d_model),
        "up": ParamSpec((cfg.n_experts, d_model, cfg.d_ff),
                        ("experts", "embed", None), dtype, fan_in=d_model),
        "down": ParamSpec((cfg.n_experts, cfg.d_ff, d_model),
                          ("experts", None, "embed"), dtype, fan_in=cfg.d_ff),
    }
    if cfg.n_shared:
        specs["shared"] = mlp_specs(d_model, cfg.d_ff * cfg.n_shared, dtype)
    return specs


def _top_k_dispatch(probs: jax.Array, top_k: int, capacity: int
                    ) -> tuple[jax.Array, jax.Array]:
    """GShard dispatch/combine tensors with capacity-based token dropping.

    probs: [G, S, E] router probabilities.
    Returns (dispatch [G,S,E,C] bool-as-dtype, combine [G,S,E,C]).
    """
    g, s, e = probs.shape
    topv, topi = jax.lax.top_k(probs, top_k)               # [G, S, k]
    prev_counts = jnp.zeros((g, e), jnp.int32)
    dispatch = jnp.zeros((g, s, e, capacity), probs.dtype)
    combine = jnp.zeros((g, s, e, capacity), probs.dtype)
    for slot in range(top_k):
        sel = jax.nn.one_hot(topi[:, :, slot], e, dtype=jnp.int32)  # [G,S,E]
        pos = jnp.cumsum(sel, axis=1) - 1 + prev_counts[:, None, :]
        prev_counts = prev_counts + jnp.sum(sel, axis=1)
        keep = (pos < capacity) & (sel > 0)
        pos_c = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                               capacity, dtype=probs.dtype)  # [G,S,E,C]
        d_slot = sel.astype(probs.dtype)[..., None] * pos_c
        dispatch = dispatch + d_slot
        combine = combine + d_slot * topv[:, :, slot][:, :, None, None]
    return dispatch, combine


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, act: str = "silu",
              rules: AxisRules = DEFAULT_RULES
              ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, M] -> (out [B, S, M], aux_loss scalar).

    Tokens are regrouped into dispatch groups of ``group_size`` so the
    dispatch tensors stay O(T * E * C / E) rather than O(T * E * S).
    """
    b, s, m = x.shape
    tokens = b * s
    gs = min(cfg.group_size, tokens)
    g = tokens // gs
    # Tail tokens beyond g*gs fall into the last group via padding.
    pad = g * gs < tokens
    if pad:
        g += 1
        xt = jnp.pad(x.reshape(tokens, m), ((0, g * gs - tokens), (0, 0)))
    else:
        xt = x.reshape(tokens, m)
    xg = xt.reshape(g, gs, m)
    xg = with_logical_constraint(xg, ("expert_group", None, None), rules=rules)

    logits = (xg.astype(jnp.float32) @ p["router"])        # [G, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    z_loss = cfg.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    # load-balance auxiliary loss (Switch style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean((jax.nn.one_hot(jnp.argmax(probs, -1), cfg.n_experts,
                                  dtype=jnp.float32)), axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(me * ce) + z_loss

    capacity = max(1, int(math.ceil(gs * cfg.top_k * cfg.capacity_factor
                                    / cfg.n_experts)))
    dispatch, combine = _top_k_dispatch(probs, cfg.top_k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    xe = jnp.einsum("gsm,gsec->gecm", xg, dispatch)        # [G, E, C, M]
    xe = with_logical_constraint(
        xe, ("expert_group", "act_experts", None, None), rules=rules)
    h = ACTIVATIONS[act](jnp.einsum("gecm,emf->gecf", xe, p["gate"])) \
        * jnp.einsum("gecm,emf->gecf", xe, p["up"])
    ye = jnp.einsum("gecf,efm->gecm", h, p["down"])
    ye = with_logical_constraint(
        ye, ("expert_group", "act_experts", None, None), rules=rules)
    yg = jnp.einsum("gecm,gsec->gsm", ye, combine)         # [G, S, M]

    y = yg.reshape(g * gs, m)[:tokens].reshape(b, s, m)
    if cfg.n_shared:
        y = y + mlp_apply(p["shared"], x, act, rules)
    return y, aux
