"""Model zoo: every assigned architecture family, in pure JAX.

  layers       — shared blocks: norms, RoPE/M-RoPE, blockwise attention,
                 GQA/MLA, gated MLPs, GShard-style MoE, ParamSpec machinery
  lm           — decoder-only LM (dense / MoE / MLA / VLM) with scan-over-
                 layers, train/prefill/decode entry points
  ssm          — Mamba2 SSD (chunked state-space duality)
  hybrid       — Jamba (Mamba+attention 1:7 interleave + MoE)
  encdec       — Seamless-M4T backbone (encoder-decoder, audio frontend stub)
  cnn          — ResNet-18 / MobileNet-V2 (the paper's own workloads) with
                 the hybrid filter-wise quantization of §4
"""
