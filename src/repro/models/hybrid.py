"""Jamba-style hybrid LM: Mamba + attention interleaved 1:7, with MoE.

Structure (period of 8 layers, Jamba's attention-to-Mamba ratio):

    [mamba, mamba, mamba, ATTN, mamba, mamba, mamba, mamba]

Every layer is followed by an FFN; MoE replaces the dense MLP on every
second layer (odd in-period indices). The model scans over *periods*
(each period's parameters stacked on the leading axis) and unrolls the
8 heterogeneous sub-layers inside the scan body — HLO size stays
bounded by one period regardless of depth.

Decode carries a hybrid cache per period: 7 recurrent SSD states + 1 KV
cache — the attention KV cache is the only O(S) memory, which is what
makes the 500k-token decode shape feasible (4 attention layers for the
32-layer config).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import lm as lm_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ParamSpec
from repro.parallel.sharding import AxisRules, DEFAULT_RULES, with_logical_constraint


PERIOD = 8
ATTN_POS = 3          # in-period index of the attention layer
MOE_POS = (1, 3, 5, 7)  # in-period indices with MoE FFN (every 2nd layer)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int                      # must be a multiple of PERIOD
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    ssm: ssm_mod.SSMConfig
    moe: L.MoEConfig
    vocab_pad_multiple: int = 256
    rope_theta: float = 10000.0
    act: str = "silu"
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: str = "none"
    scan_unroll: bool = False
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def n_periods(self) -> int:
        if self.n_layers % PERIOD:
            raise ValueError(f"n_layers {self.n_layers} % {PERIOD} != 0")
        return self.n_layers // PERIOD

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    def as_lm(self) -> lm_mod.LMConfig:
        """Attention sub-layer view (reuses lm.py attention)."""
        return lm_mod.LMConfig(
            name=self.name, n_layers=1, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim, d_ff=self.d_ff, vocab=self.vocab,
            rope_theta=self.rope_theta, act=self.act,
            param_dtype=self.param_dtype, norm_eps=self.norm_eps,
            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)


# ---------------------------------------------------------------------------
# Param specs (one period, stacked over periods)
# ---------------------------------------------------------------------------


def _period_specs(cfg: HybridConfig) -> dict:
    dt = cfg.param_dtype
    n_mamba = PERIOD - 1
    n_moe = len(MOE_POS)
    n_mlp = PERIOD - n_moe
    specs = {
        "mamba": L.stack_specs(
            {"ln": L.rmsnorm_spec(cfg.d_model, dt),
             "ssm": ssm_mod.block_specs(cfg.ssm, dt)}, n_mamba,
            axis_name="sublayers"),
        "attn": {"ln": L.rmsnorm_spec(cfg.d_model, dt),
                 "attn": lm_mod._attn_specs(cfg.as_lm())},
        "moe": L.stack_specs(
            {"ln": L.rmsnorm_spec(cfg.d_model, dt),
             "ffn": L.moe_specs(cfg.d_model, cfg.moe, dt)}, n_moe,
            axis_name="sublayers"),
        "mlp": L.stack_specs(
            {"ln": L.rmsnorm_spec(cfg.d_model, dt),
             "ffn": L.mlp_specs(cfg.d_model, cfg.d_ff, dt)}, n_mlp,
            axis_name="sublayers"),
    }
    return specs


def param_specs(cfg: HybridConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), dt, "embed"),
        "periods": L.stack_specs(_period_specs(cfg), cfg.n_periods,
                                 axis_name="layers"),
        "ln_f": L.rmsnorm_spec(cfg.d_model, dt),
        "unembed": ParamSpec((cfg.d_model, cfg.padded_vocab),
                             ("embed", "vocab"), dt),
    }


def init(cfg: HybridConfig, rng: jax.Array) -> dict:
    return L.init_params(param_specs(cfg), rng)


def abstract(cfg: HybridConfig) -> dict:
    return L.abstract_params(param_specs(cfg))


def param_axes(cfg: HybridConfig) -> dict:
    return L.param_axes_tree(param_specs(cfg))


def param_count(cfg: HybridConfig) -> int:
    return L.param_count(param_specs(cfg))


def active_param_count(cfg: HybridConfig) -> int:
    total = param_count(cfg)
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    expert_params = 3 * cfg.d_model * cfg.moe.d_ff
    total -= cfg.n_periods * len(MOE_POS) * (e - k) * expert_params
    return total


# ---------------------------------------------------------------------------
# Period body
# ---------------------------------------------------------------------------


def _take(tree: Any, i: int) -> Any:
    return jax.tree.map(lambda x: x[i], tree)


def _period_apply(p: dict, x: jax.Array, positions: jax.Array,
                  cfg: HybridConfig, rules: AxisRules,
                  cache: dict | None = None, cache_len=None
                  ) -> tuple[jax.Array, jax.Array, dict | None]:
    lm_cfg = cfg.as_lm()
    aux = jnp.float32(0.0)
    new_cache: dict[str, Any] = {"mamba": [], "attn": None} \
        if cache is not None else None
    i_mamba = i_moe = i_mlp = 0
    for pos in range(PERIOD):
        # ---- token mixer
        if pos == ATTN_POS:
            pa = p["attn"]
            h, kv_new = lm_mod._attention(
                pa["attn"], L.rmsnorm(x, pa["ln"], cfg.norm_eps), positions,
                lm_cfg, rules,
                cache=None if cache is None else cache["attn"],
                cache_len=cache_len)
            if cache is not None:
                new_cache["attn"] = kv_new
        else:
            pm = _take(p["mamba"], i_mamba)
            h, ssm_new = ssm_mod.block_apply(
                pm["ssm"], L.rmsnorm(x, pm["ln"], cfg.norm_eps), cfg.ssm,
                rules, cache=None if cache is None
                else _take(cache["mamba"], i_mamba))
            if cache is not None:
                new_cache["mamba"].append(ssm_new)
            i_mamba += 1
        x = x + h
        x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)
        # ---- FFN
        if pos in MOE_POS:
            pf = _take(p["moe"], i_moe)
            h, aux_i = L.moe_apply(pf["ffn"],
                                   L.rmsnorm(x, pf["ln"], cfg.norm_eps),
                                   cfg.moe, cfg.act, rules)
            aux = aux + aux_i
            i_moe += 1
        else:
            pf = _take(p["mlp"], i_mlp)
            h = L.mlp_apply(pf["ffn"], L.rmsnorm(x, pf["ln"], cfg.norm_eps),
                            cfg.act, rules)
            i_mlp += 1
        x = x + h
        x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)
    if new_cache is not None:
        new_cache["mamba"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_cache["mamba"])
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Forward / decode
# ---------------------------------------------------------------------------


def forward(params: dict, tokens: jax.Array, cfg: HybridConfig,
            rules: AxisRules = DEFAULT_RULES,
            positions: jax.Array | None = None,
            extra_embed: jax.Array | None = None,
            last_only: bool = False,
            slice_vocab: bool = True) -> tuple[jax.Array, jax.Array]:
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)

    def body(carry, p_period):
        x, aux = carry
        def inner(x):
            return _period_apply(p_period, x, positions, cfg, rules)[:2]
        fn = inner
        if cfg.remat == "full":
            fn = jax.checkpoint(inner,
                                policy=jax.checkpoint_policies.nothing_saveable)
        y, aux_i = fn(x)
        return (y, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               params["periods"], unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = (x @ params["unembed"]).astype(jnp.float32)
    logits = with_logical_constraint(logits, ("batch", None, "vocab_act"),
                                     rules=rules)
    if not slice_vocab:
        return logits, aux
    return logits[..., :cfg.vocab], aux


def cache_specs(cfg: HybridConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16) -> dict:
    period = {
        "mamba": L.stack_specs(
            ssm_mod.block_cache_specs(cfg.ssm, batch, dtype), PERIOD - 1,
            axis_name="sublayers"),
        "attn": {
            "k": ParamSpec((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
            "v": ParamSpec((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
        },
    }
    return {"periods": L.stack_specs(period, cfg.n_periods,
                                     axis_name="layers")}


def init_cache(cfg: HybridConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return L.init_params(cache_specs(cfg, batch, max_seq, dtype),
                         jax.random.key(0))


def decode_step(params: dict, token: jax.Array, cache: dict,
                cache_len, cfg: HybridConfig,
                rules: AxisRules = DEFAULT_RULES,
                extra_embed: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
    b = token.shape[0]
    idx = jnp.asarray(cache_len, jnp.int32)
    positions = jnp.broadcast_to(idx.reshape(-1, 1), (b, 1)).astype(jnp.int32)
    x = params["embed"][token]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)

    def body(x, xs):
        p_period, c_period = xs
        y, _, c_new = _period_apply(p_period, x, positions, cfg, rules,
                                    cache=c_period, cache_len=idx)
        return y, c_new

    x, cache_periods = jax.lax.scan(body, x, (params["periods"],
                                              cache["periods"]),
                                    unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits[..., :cfg.vocab], {"periods": cache_periods}
