"""Seamless-M4T-v2 backbone: encoder-decoder transformer.

Per the task spec the modality frontend is a STUB — ``input_specs``
provides precomputed speech *frame embeddings* [B, S_src, d_model]
(what the real model's conformer feature extractor would emit); the
text decoder is a standard causal transformer with cross-attention.

Encoder: bidirectional self-attention + MLP, scanned.
Decoder: causal self-attention + cross-attention + MLP, scanned.
Decode caches per layer: self KV (grows) + cross KV (computed once from
the encoder memory at prefill, static afterwards).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamSpec
from repro.parallel.sharding import AxisRules, DEFAULT_RULES, with_logical_constraint


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    vocab_pad_multiple: int = 256
    rope_theta: float = 10000.0
    act: str = "relu"                    # seamless uses ReLU FFNs
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: str = "none"
    scan_unroll: bool = False
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: EncDecConfig, cross: bool = False) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, hq * hd), ("embed", "heads"), dt),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), dt),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), dt),
        "wo": ParamSpec((hq * hd, d), ("heads", "embed"), dt),
    }


def _enc_layer_specs(cfg: EncDecConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "ln_attn": L.rmsnorm_spec(cfg.d_model, dt),
        "attn": _attn_specs(cfg),
        "ln_mlp": L.rmsnorm_spec(cfg.d_model, dt),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, dt),
    }


def _dec_layer_specs(cfg: EncDecConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "ln_self": L.rmsnorm_spec(cfg.d_model, dt),
        "self_attn": _attn_specs(cfg),
        "ln_cross": L.rmsnorm_spec(cfg.d_model, dt),
        "cross_attn": _attn_specs(cfg, cross=True),
        "ln_mlp": L.rmsnorm_spec(cfg.d_model, dt),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, dt),
    }


def param_specs(cfg: EncDecConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), dt, "embed"),
        "enc_layers": L.stack_specs(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "ln_enc": L.rmsnorm_spec(cfg.d_model, dt),
        "dec_layers": L.stack_specs(_dec_layer_specs(cfg), cfg.n_dec_layers),
        "ln_dec": L.rmsnorm_spec(cfg.d_model, dt),
        "unembed": ParamSpec((cfg.d_model, cfg.padded_vocab),
                             ("embed", "vocab"), dt),
    }


def init(cfg: EncDecConfig, rng: jax.Array) -> dict:
    return L.init_params(param_specs(cfg), rng)


def abstract(cfg: EncDecConfig) -> dict:
    return L.abstract_params(param_specs(cfg))


def param_axes(cfg: EncDecConfig) -> dict:
    return L.param_axes_tree(param_specs(cfg))


def param_count(cfg: EncDecConfig) -> int:
    return L.param_count(param_specs(cfg))


# ---------------------------------------------------------------------------
# Attention helpers
# ---------------------------------------------------------------------------


def _self_attention(p: dict, x: jax.Array, positions: jax.Array,
                    cfg: EncDecConfig, rules: AxisRules, causal: bool,
                    cache: dict | None = None, cache_len=None
                    ) -> tuple[jax.Array, dict | None]:
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = with_logical_constraint(
        q, ("batch", "act_seq_attn", "act_heads", None), rules=rules)
    if cache is None:
        out = L.blockwise_attention(q, k, v, causal=causal,
                                    q_chunk=cfg.q_chunk,
                                    kv_chunk=cfg.kv_chunk)
        new_cache = None
    else:
        idx = jnp.asarray(cache_len, jnp.int32)
        k_cache = L.cache_write(cache["k"], k, idx)
        v_cache = L.cache_write(cache["v"], v, idx)
        out = L.decode_attention(q, k_cache, v_cache, kv_len=idx + s)
        new_cache = {"k": k_cache, "v": v_cache}
    out = out.reshape(b, s, hq * hd)
    return out @ p["wo"], new_cache


def _cross_attention(p: dict, x: jax.Array, memory: jax.Array | None,
                     cfg: EncDecConfig, rules: AxisRules,
                     kv_cache: dict | None = None) -> jax.Array:
    """memory: [B, S_src, M] (train/prefill) or kv_cache holds
    precomputed cross K/V (decode)."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    q = with_logical_constraint(
        q, ("batch", "act_seq_attn", "act_heads", None), rules=rules)
    if kv_cache is not None:
        k, v = kv_cache["k"], kv_cache["v"]
    else:
        src = memory.shape[1]
        k = (memory @ p["wk"]).reshape(b, src, hkv, hd)
        v = (memory @ p["wv"]).reshape(b, src, hkv, hd)
    out = L.blockwise_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.reshape(b, s, hq * hd)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------


def encode(params: dict, frames: jax.Array, cfg: EncDecConfig,
           rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    """frames: [B, S_src, d_model] precomputed frame embeddings (stub
    frontend). Returns encoder memory [B, S_src, d_model]."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = frames.astype(cfg.param_dtype)
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)

    def body(x, p):
        def inner(x):
            h, _ = _self_attention(p["attn"],
                                   L.rmsnorm(x, p["ln_attn"], cfg.norm_eps),
                                   positions, cfg, rules, causal=False)
            x = x + h
            x = x + L.mlp_apply(p["mlp"],
                                L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps),
                                cfg.act, rules)
            return with_logical_constraint(x, ("batch", "act_res", None),
                                           rules=rules)
        fn = inner
        if cfg.remat == "full":
            fn = jax.checkpoint(inner,
                                policy=jax.checkpoint_policies.nothing_saveable)
        return fn(x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _decoder_stack(params: dict, x: jax.Array, positions: jax.Array,
                   memory: jax.Array, cfg: EncDecConfig, rules: AxisRules
                   ) -> jax.Array:
    def body(x, p):
        def inner(x):
            h, _ = _self_attention(p["self_attn"],
                                   L.rmsnorm(x, p["ln_self"], cfg.norm_eps),
                                   positions, cfg, rules, causal=True)
            x = x + h
            x = x + _cross_attention(p["cross_attn"],
                                     L.rmsnorm(x, p["ln_cross"],
                                               cfg.norm_eps),
                                     memory, cfg, rules)
            x = x + L.mlp_apply(p["mlp"],
                                L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps),
                                cfg.act, rules)
            return with_logical_constraint(x, ("batch", "act_res", None),
                                           rules=rules)
        fn = inner
        if cfg.remat == "full":
            fn = jax.checkpoint(inner,
                                policy=jax.checkpoint_policies.nothing_saveable)
        return fn(x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=cfg.scan_unroll)
    return x


def forward(params: dict, frames: jax.Array, tokens: jax.Array,
            cfg: EncDecConfig, rules: AxisRules = DEFAULT_RULES,
            last_only: bool = False,
            slice_vocab: bool = True) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward. Returns (logits, aux=0)."""
    memory = encode(params, frames, cfg, rules)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]
    x = _decoder_stack(params, x, positions, memory, cfg, rules)
    x = L.rmsnorm(x, params["ln_dec"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = (x @ params["unembed"]).astype(jnp.float32)
    logits = with_logical_constraint(logits, ("batch", None, "vocab_act"),
                                     rules=rules)
    if not slice_vocab:
        return logits, jnp.float32(0.0)
    return logits[..., :cfg.vocab], jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: EncDecConfig, batch: int, max_tgt: int, src: int,
                dtype=jnp.bfloat16) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    layer = {
        "self": {
            "k": ParamSpec((batch, max_tgt, hkv, hd),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
            "v": ParamSpec((batch, max_tgt, hkv, hd),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
        },
        "cross": {
            "k": ParamSpec((batch, src, hkv, hd),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
            "v": ParamSpec((batch, src, hkv, hd),
                           ("batch", "kv_seq", "act_kv_heads", None),
                           dtype, "zeros"),
        },
    }
    return {"layers": L.stack_specs(layer, cfg.n_dec_layers)}


def init_cache(cfg: EncDecConfig, batch: int, max_tgt: int, src: int,
               dtype=jnp.bfloat16) -> dict:
    return L.init_params(cache_specs(cfg, batch, max_tgt, src, dtype),
                         jax.random.key(0))


def build_cross_cache(params: dict, memory: jax.Array, cfg: EncDecConfig,
                      cache: dict, dtype=jnp.bfloat16) -> dict:
    """Fill the static cross-attention K/V from encoder memory."""
    b, src, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def per_layer(p_layer):
        k = (memory @ p_layer["cross_attn"]["wk"]).reshape(b, src, hkv, hd)
        v = (memory @ p_layer["cross_attn"]["wv"]).reshape(b, src, hkv, hd)
        return k.astype(dtype), v.astype(dtype)

    ks, vs = jax.lax.map(per_layer, params["dec_layers"])
    new_cache = dict(cache)
    new_cache["layers"] = dict(cache["layers"])
    new_cache["layers"]["cross"] = {"k": ks, "v": vs}
    return new_cache


def decode_step(params: dict, token: jax.Array, cache: dict, cache_len,
                cfg: EncDecConfig, rules: AxisRules = DEFAULT_RULES
                ) -> tuple[jax.Array, dict]:
    """One decoder token; cross K/V must already be in the cache."""
    b = token.shape[0]
    idx = jnp.asarray(cache_len, jnp.int32)
    positions = jnp.broadcast_to(idx.reshape(-1, 1), (b, 1)).astype(jnp.int32)
    x = params["embed"][token]

    def body(x, xs):
        p, c = xs
        h, self_new = _self_attention(
            p["self_attn"], L.rmsnorm(x, p["ln_self"], cfg.norm_eps),
            positions, cfg, rules, causal=True, cache=c["self"],
            cache_len=idx)
        x = x + h
        x = x + _cross_attention(p["cross_attn"],
                                 L.rmsnorm(x, p["ln_cross"], cfg.norm_eps),
                                 None, cfg, rules, kv_cache=c["cross"])
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps),
                            cfg.act, rules)
        return x, {"self": self_new, "cross": c["cross"]}

    x, cache_layers = jax.lax.scan(body, x, (params["dec_layers"],
                                             cache["layers"]),
                                   unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["ln_dec"], cfg.norm_eps)
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits[..., :cfg.vocab], {"layers": cache_layers}
