"""Mamba2 — state-space duality (SSD), chunked, in pure JAX.

The SSD form (Dao & Gu, 2024) computes the selective-SSM recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t ⊗ x_t),   y_t = C_t · h_t

as a block decomposition over sequence chunks: a quadratic *intra-chunk*
term (a masked attention-like matmul — MXU friendly) plus a linear
*inter-chunk* recurrence over per-chunk states (a short ``lax.scan``).
Peak memory is O(S * Lc) instead of O(S^2), and the chunk length ``Lc``
plays exactly the role of a kernel block size.

Decode keeps a recurrent state [B, H, P, N] plus a short conv window —
O(1) per token, which is what makes the ``long_500k`` shape runnable.

Layer structure (Mamba2 block):
    in: z, x = W_z u, W_x u;  B, C = W_b u, W_c u;  dt = softplus(W_dt u + bias)
    x, B, C <- causal depthwise conv (kernel 4) + silu
    y = SSD(x, dt, A, B, C) + D ⊙ x
    out = W_o (rmsnorm(y) * silu(z))        (gated norm)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamSpec
from repro.parallel.sharding import AxisRules, DEFAULT_RULES, with_logical_constraint


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int                 # = expand * d_model (2x)
    head_dim: int = 64           # P
    d_state: int = 128           # N
    n_groups: int = 1            # G (B/C shared across heads per group)
    conv_kernel: int = 4
    chunk: int = 256             # Lc
    dt_min: float = 1e-3
    dt_max: float = 1e-1

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class SSMLMConfig:
    """Decoder-only Mamba2 LM (mamba2-780m)."""
    name: str
    n_layers: int
    d_model: int
    vocab: int
    ssm: SSMConfig
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: str = "none"
    scan_unroll: bool = False

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def block_specs(cfg: SSMConfig, dtype=jnp.bfloat16) -> dict:
    m, di, gn, h = cfg.d_model, cfg.d_inner, cfg.n_groups * cfg.d_state, \
        cfg.n_heads
    k = cfg.conv_kernel
    return {
        "wz": ParamSpec((m, di), ("embed", "mlp"), dtype),
        "wx": ParamSpec((m, di), ("embed", "mlp"), dtype),
        "wb": ParamSpec((m, gn), ("embed", None), dtype),
        "wc": ParamSpec((m, gn), ("embed", None), dtype),
        "wdt": ParamSpec((m, h), ("embed", None), dtype),
        "conv_x": ParamSpec((k, di), (None, "mlp"), dtype),
        "conv_b": ParamSpec((k, gn), (None, None), dtype),
        "conv_c": ParamSpec((k, gn), (None, None), dtype),
        "a_log": ParamSpec((h,), (None,), jnp.float32, "zeros"),
        "d_skip": ParamSpec((h,), (None,), jnp.float32, "ones"),
        "dt_bias": ParamSpec((h,), (None,), jnp.float32, "zeros"),
        "norm": L.rmsnorm_spec(di, dtype),
        "wo": ParamSpec((di, m), ("mlp", "embed"), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, window: jax.Array | None = None
                 ) -> jax.Array:
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]. ``window`` ([B, K-1, C])
    prepends decode history instead of zero padding."""
    k = w.shape[0]
    if window is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([window.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):                       # small static unroll (k = 4)
        out = out + xp[:, i:i + s].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD
# ---------------------------------------------------------------------------


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, cfg: SSMConfig,
                initial_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """x: [B,S,H,P]; dt: [B,S,H] (positive); a: [H] (negative);
    b, c: [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    lc = min(cfg.chunk, s)
    pad = (-s) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // lc
    rep = h // g

    xc = x.reshape(bs, nc, lc, h, p).astype(jnp.float32)
    dtc = dt.reshape(bs, nc, lc, h).astype(jnp.float32)
    bc = b.reshape(bs, nc, lc, g, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, lc, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc, rep, axis=3)                     # [B,nc,Lc,H,N]
    ch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a[None, None, None, :]                    # [B,nc,Lc,H] (<0)
    da_cs = jnp.cumsum(da, axis=2)

    # intra-chunk (masked quadratic term)
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # [B,nc,i,j,H]
    ii = jnp.arange(lc)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", ch, bh)
    att = cb * decay * dtc[:, :, None, :, :]             # weight by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # chunk states: S_c = sum_j exp(da_cs[last] - da_cs[j]) dt_j B_j x_j^T
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # [B,nc,Lc,H]
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                        decay_states * dtc, bh, xc)      # [B,nc,H,P,N]

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])            # [B,nc,H]

    def scan_fn(h_prev, inp):
        dec, st = inp                                    # [B,H], [B,H,P,N]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev                             # emit state *before*

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bs, h, p, n), jnp.float32))
    final, h_prevs = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(chunk_decay, 1, 0),
                      jnp.moveaxis(states, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # [B,nc,H,P,N]

    # inter-chunk contribution: y_i += C_i · (exp(da_cs_i) * h_prev)
    y_inter = jnp.einsum("bclhn,bchpn->bclhp",
                         ch * jnp.exp(da_cs)[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(bs, nc * lc, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, state: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """One-token recurrence. x: [B,H,P]; dt: [B,H]; b, c: [B,G,N];
    state: [B,H,P,N]. Returns (y [B,H,P], new_state)."""
    h, g = x.shape[1], b.shape[1]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    da = dt.astype(jnp.float32) * a[None, :]
    decay = jnp.exp(da)[..., None, None]                 # [B,H,1,1]
    inc = (dt.astype(jnp.float32)[..., None, None]
           * x.astype(jnp.float32)[..., None] * bh[:, :, None, :])
    new_state = state.astype(jnp.float32) * decay + inc
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x.dtype), new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block forward
# ---------------------------------------------------------------------------


def block_apply(p: dict, u: jax.Array, cfg: SSMConfig,
                rules: AxisRules = DEFAULT_RULES,
                cache: dict | None = None
                ) -> tuple[jax.Array, dict | None]:
    """u: [B, S, M]. With ``cache`` (decode): S == 1, cache holds
    {"state": [B,H,P,N], "conv": [B,K-1, d_inner + 2GN]}."""
    bs, s, _ = u.shape
    h, pdim, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    gn = g * n

    z = u @ p["wz"]
    x = u @ p["wx"]
    b = u @ p["wb"]
    c = u @ p["wc"]
    dt_raw = (u @ p["wdt"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])

    xbc = jnp.concatenate([x, b, c], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1)
    if cache is None:
        xbc_conv = _causal_conv(xbc, conv_w)
        new_cache = None
        conv_window = None
    else:
        conv_window = cache["conv"]
        xbc_conv = _causal_conv(xbc, conv_w, window=conv_window)
        new_window = jnp.concatenate([conv_window[:, 1:],
                                      xbc.astype(conv_window.dtype)], axis=1)
        new_cache = {"conv": new_window}
    xbc_conv = jax.nn.silu(xbc_conv)
    x, b, c = jnp.split(xbc_conv, [cfg.d_inner, cfg.d_inner + gn], axis=-1)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])
    xh = x.reshape(bs, s, h, pdim)
    xh = with_logical_constraint(xh, ("batch", None, "act_heads", None),
                                 rules=rules)
    bg = b.reshape(bs, s, g, n)
    cg = c.reshape(bs, s, g, n)

    if cache is None:
        y, _ = ssd_chunked(xh, dt, a, bg, cg, cfg)
    else:
        y1, new_state = ssd_step(xh[:, 0], dt[:, 0], a, bg[:, 0], cg[:, 0],
                                 cache["state"])
        y = y1[:, None]
        new_cache["state"] = new_state

    y = y + xh * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bs, s, cfg.d_inner)
    y = L.rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    return y @ p["wo"], new_cache


def block_cache_specs(cfg: SSMConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    gn = cfg.n_groups * cfg.d_state
    return {
        "state": ParamSpec((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           ("batch", "act_heads", None, None), jnp.float32,
                           "zeros"),
        "conv": ParamSpec((batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * gn),
                          ("batch", None, "mlp"), dtype, "zeros"),
    }


# ---------------------------------------------------------------------------
# Mamba2 LM
# ---------------------------------------------------------------------------


def param_specs(cfg: SSMLMConfig) -> dict:
    dt = cfg.param_dtype
    layer = {
        "ln": L.rmsnorm_spec(cfg.d_model, dt),
        "ssm": block_specs(cfg.ssm, dt),
    }
    specs = {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), dt, "embed"),
        "layers": L.stack_specs(layer, cfg.n_layers),
        "ln_f": L.rmsnorm_spec(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), dt)
    return specs


def init(cfg: SSMLMConfig, rng: jax.Array) -> dict:
    params = L.init_params(param_specs(cfg), rng)
    # a_log init: A in [1, 16] (mamba2 default), dt_bias ~ softplus-inv of
    # a log-uniform dt in [dt_min, dt_max].
    def fix(layer_p):
        h = cfg.ssm.n_heads
        a0 = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
        dt0 = jnp.exp(jnp.linspace(jnp.log(cfg.ssm.dt_min),
                                   jnp.log(cfg.ssm.dt_max), h))
        inv_softplus = jnp.log(jnp.expm1(dt0))
        layer_p["ssm"]["a_log"] = jnp.broadcast_to(
            a0, layer_p["ssm"]["a_log"].shape)
        layer_p["ssm"]["dt_bias"] = jnp.broadcast_to(
            inv_softplus, layer_p["ssm"]["dt_bias"].shape)
        return layer_p
    params["layers"] = fix(params["layers"])
    return params


def abstract(cfg: SSMLMConfig) -> dict:
    return L.abstract_params(param_specs(cfg))


def param_axes(cfg: SSMLMConfig) -> dict:
    return L.param_axes_tree(param_specs(cfg))


def param_count(cfg: SSMLMConfig) -> int:
    return L.param_count(param_specs(cfg))


def forward(params: dict, tokens: jax.Array, cfg: SSMLMConfig,
            rules: AxisRules = DEFAULT_RULES,
            positions: jax.Array | None = None,
            extra_embed: jax.Array | None = None,
            last_only: bool = False,
            slice_vocab: bool = True) -> tuple[jax.Array, jax.Array]:
    x = params["embed"][tokens]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)
    x = with_logical_constraint(x, ("batch", "act_res", None), rules=rules)

    def body(x, p_layer):
        def inner(x):
            y, _ = block_apply(p_layer["ssm"],
                               L.rmsnorm(x, p_layer["ln"], cfg.norm_eps),
                               cfg.ssm, rules)
            return x + y
        fn = inner
        if cfg.remat == "full":
            fn = jax.checkpoint(inner,
                                policy=jax.checkpoint_policies.nothing_saveable)
        return fn(x), None

    x, _ = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = (x @ unembed).astype(jnp.float32)
    logits = with_logical_constraint(logits, ("batch", None, "vocab_act"),
                                     rules=rules)
    if not slice_vocab:
        return logits, jnp.float32(0.0)
    return logits[..., :cfg.vocab], jnp.float32(0.0)


def cache_specs(cfg: SSMLMConfig, batch: int, max_seq: int = 0,
                dtype=jnp.bfloat16) -> dict:
    del max_seq  # recurrent state is O(1) in sequence length
    return {"layers": L.stack_specs(
        block_cache_specs(cfg.ssm, batch, dtype), cfg.n_layers)}


def init_cache(cfg: SSMLMConfig, batch: int, max_seq: int = 0,
               dtype=jnp.bfloat16) -> dict:
    return L.init_params(cache_specs(cfg, batch, max_seq, dtype),
                         jax.random.key(0))


def decode_step(params: dict, token: jax.Array, cache: dict,
                cache_len, cfg: SSMLMConfig,
                rules: AxisRules = DEFAULT_RULES,
                extra_embed: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
    del cache_len  # state is positionless
    x = params["embed"][token]
    if extra_embed is not None:
        x = x + extra_embed.astype(x.dtype)

    def body(x, xs):
        p_layer, c_layer = xs
        y, c_new = block_apply(p_layer["ssm"],
                               L.rmsnorm(x, p_layer["ln"], cfg.norm_eps),
                               cfg.ssm, rules, cache=c_layer)
        return x + y, c_new

    x, cache_layers = jax.lax.scan(body, x, (params["layers"],
                                             cache["layers"]),
                                   unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = (x[:, 0] @ unembed).astype(jnp.float32)
    return logits[..., :cfg.vocab], {"layers": cache_layers}
